"""CSR route-table parity suite: the padded-CSR representation
(topology.route_idx, threaded through core.power / solvers / kernels) must
reproduce the dense [P, P, N] path-incidence semantics EXACTLY.

The dense tensor no longer exists in production -- it is rebuilt here via
``CFNTopology.dense_path_nodes()`` (the test-side reference constructor) and
every production quantity (lam, delta_move, delta_sweep, attribute_power) is
checked against dense references and the float64 oracle in kernels/ref.py,
under random topologies (random access trees + ring cores) and churn traces.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import dynamic, hardware as hw, power, solvers, topology, vsr
from repro.kernels import ref

SETTINGS = dict(deadline=None, max_examples=8)


def random_topology(seed: int, n_iot: int = 6, n_net_extra: int = 4
                    ) -> topology.CFNTopology:
    """A random CFN-shaped substrate: a random tree of network nodes with
    IoT/fog/cloud processing nodes attached at random points."""
    rng = np.random.default_rng(seed)
    t = topology.CFNTopology()
    for i in range(n_iot):
        t.add_proc(f"iot{i}", hw.IOT_RPI4, topology.LAYER_IOT)
    t.add_proc("af0", hw.AF_I5, topology.LAYER_AF)
    t.add_proc("mf0", hw.MF_I5, topology.LAYER_MF)
    t.add_proc("cdc0", hw.CDC_XEON, topology.LAYER_CDC)
    n_net = 3 + n_net_extra
    kinds = [hw.ONU_AP, hw.OLT, hw.METRO_ROUTER, hw.METRO_SWITCH,
             hw.IPWDM_NODE, hw.LOW_END_ROUTER, hw.LOW_END_SWITCH]
    for n in range(n_net):
        t.add_net(f"net{n}", kinds[int(rng.integers(0, len(kinds)))])
    # random tree over network nodes (node i attaches to a previous node)
    for n in range(1, n_net):
        t.connect(f"net{n}", f"net{int(rng.integers(0, n))}")
    # every processing node hangs off a random network node
    for name in t.proc_names:
        t.connect(name, f"net{int(rng.integers(0, n_net))}")
    # occasionally close a loop (meshed core: routes stay shortest-path)
    if rng.random() < 0.5 and n_net >= 4:
        a, b = rng.choice(n_net, size=2, replace=False)
        t.connect(f"net{a}", f"net{b}")
    return t.finalize()


def _dense_lam_f64(topo, prob, tm):
    dense = topo.dense_path_nodes().astype(np.float64)
    return np.einsum("pq,pqn->n", np.asarray(tm, np.float64), dense)


# ---------------------------------------------------------------------------
# representation-level parity
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000))
def test_csr_table_matches_dense(seed):
    """route_idx/route_len rebuild exactly the dense incidence tensor."""
    t = random_topology(seed)
    dense = t.dense_path_nodes()
    assert t.route_idx.shape == (t.P, t.P, t.K)
    # row sums == route lengths == hop counts
    np.testing.assert_array_equal(dense.sum(-1), t.route_len)
    np.testing.assert_array_equal(t.route_len, t.path_hops)
    # sentinel-padded: ids beyond route_len are exactly N
    k = np.arange(t.K)[None, None, :]
    pad = k >= t.route_len[:, :, None]
    assert np.all(t.route_idx[pad] == t.N)
    assert np.all(t.route_idx[~pad] < t.N)
    # routes are symmetric as SETS (dense symmetric)
    np.testing.assert_array_equal(dense, dense.transpose(1, 0, 2))


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 5))
def test_lam_csr_vs_dense(seed, n):
    """Production lambda (both the per-link hard path and the tm
    segment-sum) equals the dense einsum on random topologies."""
    t = random_topology(seed)
    vs = vsr.random_vsrs(n, rng=seed, source_nodes=[0])
    prob = power.build_problem(t, vs)
    rng = np.random.default_rng(seed)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    Xp = np.asarray(power.apply_pins(prob, jnp.asarray(X)))
    onehot = jax.nn.one_hot(jnp.asarray(Xp), prob.P, dtype=jnp.float32)
    om, tm, lam_links, th = power._loads(prob, onehot,
                                         jnp.asarray(Xp.reshape(-1)))
    _, _, lam_tm, _ = power._loads(prob, onehot)
    want = _dense_lam_f64(t, prob, np.asarray(tm))
    np.testing.assert_allclose(np.asarray(lam_links), want,
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(np.asarray(lam_tm), want,
                               rtol=1e-5, atol=1e-2)
    # f64 oracle's sparse lambda is exact vs the dense f64 contraction
    lam_f64 = ref.lam_f64_sparse(prob, np.asarray(tm, np.float64))
    np.testing.assert_allclose(lam_f64, want, rtol=1e-12, atol=1e-9)


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000))
def test_objective_f64_sparse_vs_dense(seed):
    """The f64 oracle on the sparse form == an independent dense-form f64
    objective, bit-tight (same placement, same terms)."""
    t = random_topology(seed)
    vs = vsr.random_vsrs(3, rng=seed, source_nodes=[0])
    prob = power.build_problem(t, vs)
    rng = np.random.default_rng(seed)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    got = ref.placement_objective_f64(prob, X)

    # independent dense reference
    p = prob
    Xp = np.where(np.asarray(p.fixed_mask), np.asarray(p.fixed_node), X)
    onehot = np.eye(p.P, dtype=np.float64)[Xp]
    F = np.asarray(p.F, np.float64)
    h = np.asarray(p.link_h, np.float64)
    flat = onehot.reshape(-1, p.P)
    u, w = flat[np.asarray(p.link_src)], flat[np.asarray(p.link_dst)]
    omega = np.einsum("rvp,rv->p", onehot, F)
    tm = np.einsum("l,lp,lq->pq", h, u, w)
    intra = np.einsum("l,lp,lp->p", h, u, w)
    lam = _dense_lam_f64(t, prob, tm)
    theta = (u.T @ h) + (w.T @ h) - intra
    g = lambda a: np.asarray(a, np.float64)
    n_srv = np.ceil(omega / g(p.C_pr))
    beta = (lam > power.ACTIVE_EPS).astype(np.float64)
    phi = ((omega > power.ACTIVE_EPS)
           | (theta > power.ACTIVE_EPS)).astype(np.float64)
    per_net = g(p.pue_net) * (g(p.eps) * lam / 1e3
                              + beta * g(p.idle_share) * g(p.pi_net))
    per_proc = g(p.pue_pr) * (g(p.E) * omega + n_srv * g(p.pi_pr)
                              + g(p.EL) * theta / 1e3
                              + phi * g(p.lan_share) * g(p.pi_lan))
    relu = lambda x: np.maximum(x, 0.0)
    viol = (relu(omega - g(p.NS) * g(p.C_pr)).sum()
            + relu(lam / 1e3 - g(p.C_net)).sum()
            + relu(theta / 1e3 - g(p.C_lan)).sum())
    want = float(per_net.sum() + per_proc.sum() + power.PENALTY * viol)
    assert abs(got - want) <= 1e-9 * max(1.0, abs(want))


# ---------------------------------------------------------------------------
# delta engine on the CSR form
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000))
def test_delta_move_f64_oracle_random_topology(seed):
    """delta_move on the CSR tables matches the f64 oracle along a random
    move sequence on a random topology."""
    t = random_topology(seed)
    vs = vsr.random_vsrs(4, rng=seed, source_nodes=[0])
    prob = power.build_problem(t, vs)
    aux = power.build_aux(prob)
    rng = np.random.default_rng(seed)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    st_ = power.init_state(prob, jnp.asarray(X))
    free = np.asarray(aux.free_pos)
    for _ in range(12):
        r, v = free[rng.integers(0, len(free))]
        p_new = int(rng.integers(0, prob.P))
        got = float(power.delta_move(prob, aux, st_, int(r), int(v), p_new))
        want = ref.placement_delta_ref(prob, np.asarray(st_.X),
                                       int(r), int(v), p_new)
        assert abs(got - want) <= 5e-2, (r, v, p_new, got, want)
        st_ = power.apply_move(prob, aux, st_, int(r), int(v), p_new)
    # committed lam stays exact vs a fresh rebuild
    fresh = power.init_state(prob, st_.X)
    np.testing.assert_allclose(np.asarray(st_.lam), np.asarray(fresh.lam),
                               rtol=1e-5, atol=1e-2)


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000))
def test_delta_sweep_vs_dense_broadcast(seed):
    """delta_sweep (CSR insertion scoring) == objective_batch over the P
    explicitly-broadcast candidates."""
    t = random_topology(seed)
    vs = vsr.random_vsrs(3, rng=seed, source_nodes=[0])
    prob = power.build_problem(t, vs)
    aux = power.build_aux(prob)
    rng = np.random.default_rng(seed)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    st_ = power.init_state(prob, jnp.asarray(power.apply_pins(
        prob, jnp.asarray(X))))
    free = np.asarray(aux.free_pos)
    r, v = free[rng.integers(0, len(free))]
    got = np.asarray(power.delta_sweep(prob, aux, st_, int(r), int(v)))
    cand = np.broadcast_to(np.asarray(st_.X),
                           (prob.P,) + st_.X.shape).copy()
    cand[:, r, v] = np.arange(prob.P)
    want = np.asarray(power.objective_batch(prob, jnp.asarray(cand)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=5e-2)


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000))
def test_attribute_power_sums_random_topology(seed):
    """Per-service attribution sums exactly to the fleet total on random
    topologies (service_loads runs on the CSR tables)."""
    t = random_topology(seed)
    vs = vsr.random_vsrs(4, rng=seed, source_nodes=[0])
    prob = power.build_problem(t, vs)
    rng = np.random.default_rng(seed)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    bd = power.evaluate(prob, jnp.asarray(power.apply_pins(
        prob, jnp.asarray(X))))
    per = power.attribute_power(prob, X, bd)
    assert abs(per.sum() - float(bd.total)) <= 1e-6 * max(1.0,
                                                          float(bd.total))


# ---------------------------------------------------------------------------
# shape bucketing + SLA admission (solver/online layer)
# ---------------------------------------------------------------------------

def test_padded_problem_is_load_invariant():
    """Bucket pad rows (zero-demand, fully pinned) change NOTHING: same
    objective, same loads, zero extra free positions."""
    t = topology.paper_topology()
    vs = vsr.random_vsrs(5, rng=3, source_nodes=[0])
    prob = power.build_problem(t, vs)
    prob_p = power.build_problem(t, vs, pad_to_rows=8)
    assert prob_p.R == 8 and prob.R == 5
    aux, aux_p = power.build_aux(prob), power.build_aux(prob_p)
    assert aux.free_pos.shape[0] == aux_p.free_pos.shape[0]
    rng = np.random.default_rng(0)
    X = rng.integers(0, prob.P, size=(5, prob.V)).astype(np.int32)
    Xp = np.concatenate([X, np.zeros((3, prob.V), np.int32)])
    o1 = float(power.objective(prob, jnp.asarray(X)))
    o2 = float(power.objective(prob_p, jnp.asarray(Xp)))
    assert abs(o1 - o2) <= 1e-5 * max(1.0, abs(o1))
    s1 = power.init_state(prob, jnp.asarray(X))
    s2 = power.init_state(prob_p, jnp.asarray(Xp))
    np.testing.assert_allclose(np.asarray(s1.lam), np.asarray(s2.lam),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1.omega), np.asarray(s2.omega),
                               atol=1e-4)


def test_bucketed_engine_consistent_and_bounded_shapes():
    """The bucketed online engine sees only power-of-two problem shapes and
    its committed state matches a from-scratch rebuild after churn."""
    t = topology.paper_topology()
    make = lambda sid: vsr.random_vsrs(1, rng=500 + sid, source_nodes=[0])
    eng = dynamic.OnlineEmbedder(t, defrag_every=0,
                                 key=jax.random.PRNGKey(3),
                                 anneal_steps=80, anneal_chains=4)
    shapes = set()
    for s in range(5):
        eng.add(make(s), sid=s)
        shapes.add(eng.problem.R)
    eng.remove(1)
    eng.remove(3)
    shapes.add(eng.problem.R)
    assert shapes <= {2, 4, 8}, shapes
    fresh = power.init_state(eng.problem, jnp.asarray(eng.X))
    assert abs(float(fresh.obj) - eng.objective()) <= \
        1e-3 + 1e-6 * abs(float(fresh.obj))
    per = eng.per_service_power_w()
    assert abs(sum(per.values()) - eng.power_w()) <= \
        1e-6 * max(1.0, eng.power_w())


def test_admission_hop_mask_and_budget():
    """max_hops keeps an admitted arrival within the hop radius; a zero
    power budget rejects and (queued) re-admits after a departure."""
    t = topology.paper_topology()
    make = lambda sid: vsr.random_vsrs(1, rng=900 + sid, source_nodes=[0])
    eng = dynamic.OnlineEmbedder(t, defrag_every=0, max_hops=2,
                                 anneal_steps=60, anneal_chains=4)
    eng.add(make(0), sid=0)
    eng.add(make(1), sid=1)
    hops = np.asarray(t.path_hops)
    row = eng.sids.index(1)
    src = int(make(1).src[0])
    assert all(hops[src, p] <= 2 for p in eng.X[row])
    # persisted masks: the FIRST service must still sit inside its radius
    # after the second event's polish sweeps touched every free VM
    row0 = eng.sids.index(0)
    src0 = int(make(0).src[0])
    assert all(hops[src0, p] <= 2 for p in eng.X[row0])
    eng.remove(1)   # survivor re-pack must also respect the mask
    row0 = eng.sids.index(0)
    assert all(hops[src0, p] <= 2 for p in eng.X[row0])

    eng2 = dynamic.OnlineEmbedder(t, defrag_every=0,
                                  admit_power_budget_w=1e4,
                                  queue_rejected=True,
                                  anneal_steps=60, anneal_chains=4)
    assert eng2.add(make(10), sid=10) is not None    # well under budget
    eng2.admit_power_budget_w = 0.0                  # now nothing fits
    assert eng2.add(make(11), sid=11) is None        # over budget
    assert eng2.admission["rejected"] == 1
    assert eng2.n_live == 1
    eng2.admit_power_budget_w = 1e4
    eng2.remove(10)                                  # queue drains
    assert 11 in eng2.sids                           # queue re-admitted
    assert eng2.admission["admitted"] == 2
    assert eng2.admission["rejected"] == 1
    rejects = [s for s in eng2.stats if s.event == "reject"]
    assert len(rejects) == 1

    # admission control applies to the FIRST service too (no bootstrap
    # bypass): a zero budget admits nothing into an empty engine
    eng3 = dynamic.OnlineEmbedder(t, defrag_every=0,
                                  admit_power_budget_w=0.0,
                                  anneal_steps=60, anneal_chains=4)
    assert eng3.add(make(20), sid=20) is None
    assert eng3.n_live == 0 and eng3.admission["rejected"] == 1


def test_resolve_incremental_eligible_mask():
    """resolve_incremental keeps the changed row inside its eligible set."""
    t = topology.paper_topology()
    vs = vsr.random_vsrs(4, rng=7, source_nodes=[0])
    prob = power.build_problem(t, vs)
    eligible = np.ones((prob.R, prob.P), bool)
    allowed = np.asarray(t.path_hops)[0] <= 2
    eligible[3] = allowed
    X0 = np.zeros((prob.R, prob.V), np.int32)
    res = solvers.resolve_incremental(
        prob, X0, key=jax.random.PRNGKey(0), changed_rows=[3],
        anneal_steps=80, anneal_chains=4, eligible=eligible)
    assert all(allowed[p] for p in res.X[3]), res.X[3]


# ---------------------------------------------------------------------------
# city-scale smoke (slow tier)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_city_scale_smoke():
    """A small city_scale instance end-to-end: CSR invariants, lam parity,
    and two online churn events."""
    t = topology.city_scale(n_olt=2, onus_per_olt=2, iot_per_onu=2,
                            n_metro=1, n_core=4, n_cdc=1)
    assert t.P == 2 * 2 * 2 + 2 + 1 + 1
    dense = t.dense_path_nodes()
    np.testing.assert_array_equal(dense.sum(-1), t.route_len)
    vs = vsr.random_vsrs(3, rng=0, source_nodes=[0])
    prob = power.build_problem(t, vs)
    rng = np.random.default_rng(0)
    X = rng.integers(0, prob.P, size=(prob.R, prob.V)).astype(np.int32)
    Xp = np.asarray(power.apply_pins(prob, jnp.asarray(X)))
    onehot = jax.nn.one_hot(jnp.asarray(Xp), prob.P, dtype=jnp.float32)
    _, tm, lam, _ = power._loads(prob, onehot, jnp.asarray(Xp.reshape(-1)))
    want = _dense_lam_f64(t, prob, np.asarray(tm))
    np.testing.assert_allclose(np.asarray(lam), want, rtol=1e-5, atol=1e-2)

    make = lambda sid: vsr.random_vsrs(1, rng=100 + sid, source_nodes=[0])
    eng = dynamic.OnlineEmbedder(t, defrag_every=0, method="coordinate",
                                 key=jax.random.PRNGKey(1),
                                 anneal_steps=40, anneal_chains=2,
                                 polish_sweeps=1)
    eng.add(make(0), sid=0)
    eng.add(make(1), sid=1)
    assert eng.result is not None and eng.n_live == 2
    fresh = power.init_state(eng.problem, jnp.asarray(eng.X))
    assert abs(float(fresh.obj) - eng.objective()) <= \
        1e-3 + 1e-6 * abs(float(fresh.obj))
