"""Wave-batched churn tests (the batched churn waves PR).

Covers: the deprecation-parity contract (a wave of size 1 is bit-identical
to the per-event ``add``/``remove`` path, f64-oracle-checked), same-tick
replace semantics (departures detach before arrivals inside one wave, so
capacity is never double-counted), ``merge_timelines`` tie-ordering as a
property, the flash-crowd preset round-trip, priority-heap admission and
drain order, queue-drain fairness after capacity-increasing events,
preemption under power-budget pressure, the amortized background defrag
tick (never-regressing, cursor carried across ticks, periodic defrag
disabled), wave compile stability (zero fresh traces after the warmup
wave per shape bucket), the scheduler's batch facade, and the federated
per-region wave path.
"""
import jax
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.api import CFNSession, FederatedSession, PlacementSpec
from repro.core import dynamic, federation, power, solvers, topology, vsr
from repro.kernels import ref as kref


@pytest.fixture(scope="module")
def topo():
    return topology.paper_topology()


def _quick_spec(**kw):
    return PlacementSpec(effort="quick", anneal_steps=0, defrag_every=0,
                         **kw)


def _services(topo, n, seed0=0, n_vms=3):
    iot = topo.layer_indices("iot")
    return [vsr.random_vsrs(1, rng=np.random.default_rng(seed0 + i),
                            n_vms=n_vms, source_nodes=iot[:4])
            for i in range(n)]


def _pair(topo, n=4, seed0=0, **spec_kw):
    """Two sessions with identical keys/specs, seeded with n live services
    via the per-event path (so only the follow-up churn differs)."""
    a = CFNSession(topo, _quick_spec(**spec_kw), key=jax.random.PRNGKey(7))
    b = CFNSession(topo, _quick_spec(**spec_kw), key=jax.random.PRNGKey(7))
    svcs = _services(topo, n, seed0=seed0)
    for i, sv in enumerate(svcs):
        assert a.add(sv, sid=i) is not None
        assert b.add(sv, sid=i) is not None
    return a, b, svcs


# ---------------------------------------------------------------------------
# deprecation parity: wave of size 1 == per-event path, bit-identical
# ---------------------------------------------------------------------------

def test_wave_of_one_arrival_is_bit_identical_to_add(topo):
    a, b, _ = _pair(topo)
    fresh = _services(topo, 1, seed0=50)[0]
    ra = a.add(fresh, sid=99)
    wr = b.apply_wave([(fresh, 99)])
    assert wr.admitted == [99] and wr.sids == [99]
    np.testing.assert_array_equal(np.asarray(a.X), np.asarray(b.X))
    assert a.engine.admission == b.engine.admission
    # identical placements must agree under the f64 oracle exactly
    oa = kref.placement_objective_f64(a.problem, np.asarray(a.engine._X))
    ob = kref.placement_objective_f64(b.problem, np.asarray(b.engine._X))
    assert oa == ob
    assert float(ra.power) == float(wr.result.power)


def test_wave_of_one_departure_is_bit_identical_to_remove(topo):
    a, b, _ = _pair(topo)
    ra = a.remove(2)
    wr = b.apply_wave(departures=[2])
    assert wr.departed == [2]
    np.testing.assert_array_equal(np.asarray(a.X), np.asarray(b.X))
    assert a.sids == b.sids
    assert a.engine.admission == b.engine.admission
    assert float(ra.power) == float(wr.result.power)


def test_empty_wave_is_a_noop(topo):
    a, _, _ = _pair(topo, n=2)
    before = np.asarray(a.X).copy()
    wr = a.apply_wave()
    assert wr.admitted == [] and wr.departed == []
    np.testing.assert_array_equal(np.asarray(a.X), before)


# ---------------------------------------------------------------------------
# wave semantics: same-tick replace, accounting, validation
# ---------------------------------------------------------------------------

def test_wave_replace_keeps_live_count_and_bucket(topo):
    s = CFNSession(topo, _quick_spec(), key=jax.random.PRNGKey(3))
    for i, sv in enumerate(_services(topo, 4)):
        s.add(sv, sid=i)
    R_pad = s.problem.R
    fresh = _services(topo, 2, seed0=70)
    wr = s.apply_wave([(fresh[0], 10), (fresh[1], 11)], departures=[0, 1])
    assert s.n_live == 4
    assert s.problem.R == R_pad        # same shape bucket: no re-compile
    assert set(s.sids) == {2, 3, 10, 11}
    assert set(wr.admitted) == {10, 11} and wr.departed == [0, 1]
    # every arrival sid lands in exactly one verdict bucket
    verdicts = wr.admitted + wr.rejected + wr.queued
    assert sorted(verdicts) == sorted(wr.sids)
    # the committed placement is coherent under the f64 oracle
    obj = kref.placement_objective_f64(s.problem, np.asarray(s.engine._X))
    assert abs(obj - float(wr.result.objective)) <= \
        5e-2 + 1e-3 * abs(obj)


def test_wave_validates_inputs(topo):
    s = CFNSession(topo, _quick_spec(), key=jax.random.PRNGKey(0))
    sv = _services(topo, 1)[0]
    s.add(sv, sid=0)
    with pytest.raises(KeyError):
        s.apply_wave(departures=[5])
    with pytest.raises(ValueError):
        s.apply_wave(departures=[0, 0])
    with pytest.raises(ValueError):
        s.apply_wave([(sv, 0)])       # sid already live
    with pytest.raises(ValueError):
        s.apply_wave([(sv, 7), (sv, 7)])


# ---------------------------------------------------------------------------
# merge_timelines tie ordering + flash-crowd preset
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=30)
@given(seed=st.integers(0, 10_000))
def test_departures_sort_before_arrivals_within_every_wave(seed):
    """Property: however the same-tick events are interleaved on input,
    every wave out of merge_timelines + iter_waves applies departures
    first -- the ordering a same-tick replace relies on to never
    double-count capacity."""
    rng = np.random.default_rng(seed)
    events = []
    for t in range(int(rng.integers(1, 4))):
        for i in range(int(rng.integers(1, 6))):
            kind = "arrive" if rng.random() < 0.5 else "depart"
            events.append(dynamic.ServiceEvent(float(t), kind,
                                               int(rng.integers(0, 50))))
    rng.shuffle(events)
    merged = dynamic.merge_timelines(events)
    waves = list(dynamic.iter_waves(merged))
    assert sum(len(w) for w in waves) == len(events)
    for wave in waves:
        assert len({e.t for e in wave}) == 1
        kinds = [e.kind for e in wave]
        first_arrive = kinds.index("arrive") if "arrive" in kinds else None
        if first_arrive is not None:
            assert all(k == "arrive" for k in kinds[first_arrive:])


def test_fault_events_are_single_event_barrier_waves():
    events = dynamic.merge_timelines(
        [dynamic.ServiceEvent(1.0, "arrive", 0),
         dynamic.ServiceEvent(1.0, "depart", 9),
         dynamic.ServiceEvent(2.0, "arrive", 1)],
        [dynamic.FaultEvent(1.0, "fail_node", 3)])
    waves = list(dynamic.iter_waves(events))
    # tie order: depart < fail < arrive, and the fault is its own wave
    assert [[e.kind for e in w] for w in waves] == \
        [["depart"], ["fail_node"], ["arrive"], ["arrive"]]


def test_flash_crowd_replace_preset_roundtrip(topo):
    events = dynamic.flash_crowd_trace(4, 3, 4, rng=0, replace=True)
    waves = list(dynamic.iter_waves(events))
    assert len(waves) == 4                      # bootstrap + 3 churn waves
    assert [len(w) for w in waves] == [4, 4, 4, 4]
    for w in waves[1:]:                         # replace: 2 out, 2 in
        assert [e.kind for e in w] == ["depart"] * 2 + ["arrive"] * 2
    s = CFNSession(topo, _quick_spec(), key=jax.random.PRNGKey(1))
    make = lambda sid: _services(topo, 1, seed0=100 + sid)[0]
    s.replay(events, make, waves=True)
    assert s.n_live == 4                        # live count never moves


def test_flash_crowd_burst_preset_drains_to_steady(topo):
    events = dynamic.flash_crowd_trace(3, 2, 3, rng=0, replace=False)
    s = CFNSession(topo, _quick_spec(), key=jax.random.PRNGKey(1))
    make = lambda sid: _services(topo, 1, seed0=200 + sid)[0]
    s.replay(events, make, waves=True)
    assert set(s.sids) == {0, 1, 2}             # crowd fully drained


def test_replay_waves_and_per_event_agree_on_live_set(topo):
    events = dynamic.flash_crowd_trace(4, 2, 4, rng=3)
    make = lambda sid: _services(topo, 1, seed0=300 + sid)[0]
    a = CFNSession(topo, _quick_spec(), key=jax.random.PRNGKey(2))
    b = CFNSession(topo, _quick_spec(), key=jax.random.PRNGKey(2))
    a.replay(events, make)
    b.replay(events, make, waves=True)
    assert set(a.sids) == set(b.sids)


# ---------------------------------------------------------------------------
# priority admission, queue-drain fairness, preemption
# ---------------------------------------------------------------------------

def test_priority_classes_validated(topo):
    s = CFNSession(topo, _quick_spec(priority_classes=2),
                   key=jax.random.PRNGKey(0))
    sv = _services(topo, 1)[0]
    with pytest.raises(ValueError):
        s.add(sv, priority=2)
    with pytest.raises(ValueError):
        s.add(sv, priority=-1)
    with pytest.raises(ValueError):
        PlacementSpec(priority_classes=0)


def test_queue_drains_in_priority_order(topo):
    s = CFNSession(topo, _quick_spec(priority_classes=3,
                                     queue_rejected=True),
                   key=jax.random.PRNGKey(0))
    anchor = _services(topo, 1)[0]
    s.add(anchor, sid=0)
    s.engine.brownout(0.0)          # nothing fits a zero-watt budget
    svcs = _services(topo, 3, seed0=40)
    for sid, prio in [(1, 2), (2, 0), (3, 1)]:
        assert s.add(svcs[sid - 1], sid=sid, priority=prio) is None
    assert s.engine.queued_sids == [2, 3, 1]    # heap order: class first
    s.engine.brownout_end()
    # drain admits class 0 first, then 1, then 2 -- reflected in row order
    assert s.sids == [0, 2, 3, 1]
    assert not s.engine._queue


def test_departure_drains_queue_until_first_rerejection(topo):
    """Satellite regression: ANY capacity-increasing event retries the
    queue until the first re-rejection -- under a still-zero budget the
    first retry re-parks and the rest never run; once the brownout lifts,
    every queued service that fits is admitted."""
    s = CFNSession(topo, _quick_spec(queue_rejected=True),
                   key=jax.random.PRNGKey(0))
    svcs = _services(topo, 5)
    for i in range(2):
        assert s.add(svcs[i], sid=i) is not None
    s.engine.brownout(0.0)
    for i in range(2, 5):
        assert s.add(svcs[i], sid=i) is None
    assert len(s.engine._queue) == 3
    s.remove(0)                     # capacity up, but budget still zero
    assert set(s.sids) == {1}
    assert len(s.engine._queue) == 3   # first retry re-parked, drain stopped
    s.engine.brownout_end()         # budget restored: full drain
    assert set(s.sids) == {1, 2, 3, 4}
    assert not s.engine._queue


def test_recovery_admits_all_queued_that_fit(topo):
    """A recovery must re-admit EVERY parked service that now fits, not
    one -- the queue-drain fairness fix."""
    s = CFNSession(topo, _quick_spec(queue_rejected=True),
                   key=jax.random.PRNGKey(0))
    iot = topo.layer_indices("iot")
    src = iot[0]
    svcs = [vsr.random_vsrs(1, rng=np.random.default_rng(10 + i),
                            n_vms=3, source_nodes=[src])
            for i in range(3)]
    for i, sv in enumerate(svcs):
        assert s.add(sv, sid=i) is not None
    s.engine.fail_node(src)         # all three strand on the dead source
    assert s.n_live == 0
    assert len(s.engine.queued_sids) == 3
    s.engine.recover_node(src)
    assert set(s.sids) == {0, 1, 2}     # one recovery, all three back
    assert not s.engine._queue


def test_preemption_parks_lower_class_for_higher(topo):
    s = CFNSession(topo, _quick_spec(priority_classes=2, preempt=True,
                                     queue_rejected=True),
                   key=jax.random.PRNGKey(0))
    svcs = _services(topo, 3)
    assert s.add(svcs[0], sid=0, priority=0) is not None
    assert s.add(svcs[1], sid=1, priority=1) is not None   # the victim
    s.engine.brownout(0.0)          # all marginal power now over budget
    s.add(svcs[2], sid=2, priority=0)
    # the power refusal preempted the newest lowest-class service ...
    assert s.engine.admission["preempted"] == 1
    assert 1 in s.engine.queued_sids
    assert 0 in s.sids
    # ... and never a same-or-higher-class one
    assert all(s.engine._prio[s.sids.index(sid)] == 0 for sid in s.sids)
    s.engine.brownout_end()
    assert set(s.sids) >= {0, 1}    # the victim returns once budget lifts


def test_wave_admission_is_priority_ordered_under_budget(topo):
    """Under a marginal power budget a wave refuses lowest class first."""
    s = CFNSession(topo, _quick_spec(priority_classes=2,
                                     queue_rejected=True),
                   key=jax.random.PRNGKey(0))
    base = _services(topo, 1)[0]
    assert s.add(base, sid=0) is not None
    s.engine.brownout(0.0)
    fresh = _services(topo, 2, seed0=60)
    wr = s.apply_wave([(fresh[0], 1, 0), (fresh[1], 2, 1)])
    # zero budget refuses both, but class 1 is chosen for refusal first
    assert set(wr.queued) == {1, 2}
    assert s.engine.queued_sids[0] == 1     # class 0 parked at heap top


# ---------------------------------------------------------------------------
# amortized background defrag
# ---------------------------------------------------------------------------

def test_defrag_tick_never_regresses_and_carries_cursor(topo):
    spec = _quick_spec(defrag_rows_per_tick=2)
    s = CFNSession(topo, spec, key=jax.random.PRNGKey(0))
    for i, sv in enumerate(_services(topo, 5)):
        s.add(sv, sid=i)
    objs = [float(s.result.objective)]
    cursors = [s.engine._defrag_cursor]
    for _ in range(6):
        res = s.defrag_tick()
        if res is not None:
            assert res.method == "defrag_tick"
        objs.append(float(s.result.objective))
        cursors.append(s.engine._defrag_cursor)
    for prev, cur in zip(objs, objs[1:]):
        assert cur <= prev + 1e-9          # never-regressing
    # round-robin cursor: advances by K mod n_live each tick
    for prev, cur in zip(cursors, cursors[1:]):
        assert cur == (prev + 2) % s.n_live


def test_defrag_rows_per_tick_disables_periodic_full_defrag(topo):
    spec = PlacementSpec(effort="quick", anneal_steps=0, defrag_every=2,
                         defrag_rows_per_tick=1)
    s = CFNSession(topo, spec, key=jax.random.PRNGKey(0))
    for i, sv in enumerate(_services(topo, 6)):
        s.add(sv, sid=i)
    # defrag_every=2 would have forced full re-packs; the amortized mode
    # keeps every event on the incremental path
    assert all(st.method != "defrag"
               for st in s.engine.stats if st.event == "add")
    assert not s.engine._defrag_due()


def test_defrag_tick_empty_engine_is_noop(topo):
    s = CFNSession(topo, _quick_spec(defrag_rows_per_tick=2),
                   key=jax.random.PRNGKey(0))
    assert s.defrag_tick() is None


# ---------------------------------------------------------------------------
# compile stability: one trace set per wave-shape bucket
# ---------------------------------------------------------------------------

def test_wave_zero_fresh_traces_after_warmup(topo):
    s = CFNSession(topo, _quick_spec(), key=jax.random.PRNGKey(0))
    for i, sv in enumerate(_services(topo, 6)):
        s.add(sv, sid=i)
    fresh = _services(topo, 8, seed0=80)
    # warmup wave: compiles the wave-bucket variants once
    s.apply_wave([(fresh[0], 10), (fresh[1], 11)], departures=[0, 1])
    before = dict(solvers.TRACE_COUNTS)
    # same bucket (2 dep + 2 arr at the same live count): zero fresh traces
    s.apply_wave([(fresh[2], 12), (fresh[3], 13)], departures=[2, 3])
    assert solvers.TRACE_COUNTS == before, \
        "a same-bucket wave must not retrace solver kernels"


def test_defrag_tick_zero_fresh_traces_after_warmup(topo):
    s = CFNSession(topo, _quick_spec(defrag_rows_per_tick=2),
                   key=jax.random.PRNGKey(0))
    for i, sv in enumerate(_services(topo, 5)):
        s.add(sv, sid=i)
    s.defrag_tick()
    before = dict(solvers.TRACE_COUNTS)
    for _ in range(4):
        s.defrag_tick()
    assert solvers.TRACE_COUNTS == before, \
        "same-bucket defrag ticks must not retrace solver kernels"


# ---------------------------------------------------------------------------
# federated per-region waves
# ---------------------------------------------------------------------------

def _fed_topo():
    return topology.federated_scale(n_regions=3, n_olt=1, onus_per_olt=2,
                                    iot_per_onu=2, n_core=6)


def test_federated_wave_batches_per_region():
    ftopo = _fed_topo()
    part = federation.RegionPartition.from_topology(ftopo)
    srcs = [int(r.proc_ids[0]) for r in part.regions]
    sess = FederatedSession(ftopo, PlacementSpec(effort="quick"),
                            key=jax.random.PRNGKey(0))
    mk = lambda sid: vsr.random_vsrs(1, rng=100 + sid,
                                     source_nodes=[srcs[sid % 3]])
    wr = sess.apply_wave([(mk(i), i) for i in range(6)])
    assert sorted(wr.admitted) == list(range(6))
    assert {sess.assignment(i) for i in range(6)} == {0, 1, 2}
    wr2 = sess.apply_wave([(mk(10), 10)], departures=[0, 3])
    assert wr2.departed == [0, 3] and wr2.admitted == [10]
    assert sess.n_live == 5
    # power accounting stays exact through the batched path
    bd = sess.breakdown()
    assert bd.total_w > 0 and np.all(np.asarray(bd.regional_w) >= 0)


def test_federated_wave_of_one_matches_per_event():
    ftopo = _fed_topo()
    part = federation.RegionPartition.from_topology(ftopo)
    srcs = [int(r.proc_ids[0]) for r in part.regions]
    mk = lambda sid: vsr.random_vsrs(1, rng=100 + sid,
                                     source_nodes=[srcs[sid % 3]])
    a = FederatedSession(ftopo, PlacementSpec(effort="quick"),
                         key=jax.random.PRNGKey(2))
    b = FederatedSession(ftopo, PlacementSpec(effort="quick"),
                         key=jax.random.PRNGKey(2))
    for i in range(3):
        a.add(mk(i), sid=i)
        b.apply_wave([(mk(i), i)])
    np.testing.assert_array_equal(np.asarray(a.X), np.asarray(b.X))
    assert a.sids == b.sids


def test_federated_replay_waves():
    ftopo = _fed_topo()
    part = federation.RegionPartition.from_topology(ftopo)
    srcs = [int(r.proc_ids[0]) for r in part.regions]
    mk = lambda sid: vsr.random_vsrs(1, rng=100 + sid,
                                     source_nodes=[srcs[sid % 3]])
    sess = FederatedSession(ftopo, PlacementSpec(effort="quick",
                                                 defrag_rows_per_tick=1),
                            key=jax.random.PRNGKey(1))
    events = dynamic.flash_crowd_trace(3, 2, 2, rng=0)
    stats = sess.replay(events, mk, waves=True)
    assert sess.n_live == 3
    assert len(stats) == len(events)


def test_federated_rejects_preempt():
    ftopo = _fed_topo()
    with pytest.raises(ValueError, match="preempt"):
        FederatedSession(ftopo, PlacementSpec(effort="quick", preempt=True),
                         key=jax.random.PRNGKey(0))
