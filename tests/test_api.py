"""Unified PlacementSpec / CFNSession API tests (the api_redesign PR).

Covers: export consistency across repro.api / repro.core / repro.core.api,
PlacementSpec pytree round-tripping, spec.masks == the legacy kwarg-path
masks, shim-vs-session result parity for every deprecated entry point,
the defrag-respects-max_hops regression (ROADMAP closure), V-width
bucketing, and the acceptance-criterion churn replay: the same trace
through CFNSession.replay and the legacy replay(OnlineEmbedder, ...) path
produces identical placements, power, and admission counters
(f64-oracle-checked), including a defrag step under an SLA hop bound.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

import repro.api as api_mod
import repro.core as core_mod
import repro.core.api as core_api_mod
from repro.api import CFNSession, PlacementSpec
from repro.core import dynamic, embed, power, solvers, topology, vsr
from repro.kernels import ref


@pytest.fixture(scope="module")
def topo():
    return topology.paper_topology()


def _quiet(fn, *a, **kw):
    """Call a deprecated shim without polluting the warning log, asserting
    it really does deprecate."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = fn(*a, **kw)
    assert any(issubclass(w.category, DeprecationWarning) for w in rec)
    return out


# ---------------------------------------------------------------------------
# exports (CI satellite): __all__ consistent, no dangling names
# ---------------------------------------------------------------------------

def test_api_exports_consistent():
    for mod in (core_mod, core_api_mod, api_mod):
        for name in mod.__all__:
            assert hasattr(mod, name), \
                f"{mod.__name__}.__all__ dangles: {name}"
    # the facade re-exports exactly the core api surface
    assert set(api_mod.__all__) == set(core_api_mod.__all__)
    # the spec/session layer is reachable from both entry points
    for name in ("PlacementSpec", "CFNSession", "solve_portfolio"):
        assert name in core_mod.__all__ and name in api_mod.__all__


def test_spec_validates_config():
    with pytest.raises(ValueError):
        PlacementSpec(method="nope")
    with pytest.raises(ValueError):
        PlacementSpec(effort="extreme")
    with pytest.raises(ValueError):
        PlacementSpec(backend="cuda")
    s = PlacementSpec().replace(effort="high")
    assert s.effort == "high" and PlacementSpec().effort == "standard"


# ---------------------------------------------------------------------------
# spec round-tripping (pytree) and mask equivalence
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(mh=st.one_of(st.none(), st.integers(0, 6)),
       effort=st.sampled_from(["quick", "standard", "high"]),
       steps=st.integers(1, 5000), brow=st.booleans(), bcol=st.booleans(),
       budget=st.one_of(st.none(), st.floats(0.0, 1e4)),
       with_el=st.booleans())
def test_spec_pytree_roundtrip(mh, effort, steps, brow, bcol, budget,
                               with_el):
    el = np.ones((3, 5), bool) if with_el else None
    if el is not None:
        el[1, ::2] = False
    spec = PlacementSpec(max_hops=mh, eligible=el, power_budget_w=budget,
                         effort=effort, anneal_steps=steps,
                         bucket_rows=brow, bucket_cols=bcol)
    leaves, treedef = jax.tree_util.tree_flatten(spec)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    for f in spec.__dataclass_fields__:
        a, b = getattr(spec, f), getattr(back, f)
        if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
            np.testing.assert_array_equal(a, b)
        else:
            assert a == b, f
    # array-valued constraints are leaves, config is static aux data
    n_leaves = len(leaves)
    assert n_leaves == (0 if mh is None else 1) + (1 if with_el else 0)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 10_000), mh=st.integers(0, 6))
def test_spec_masks_match_legacy_kwarg_masks(seed, mh):
    """spec.masks(problem) == the [R, P] stack the old kwarg paths built
    (hops[src] <= max_hops per service row, from topo.path_hops)."""
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(4, rng=seed, source_nodes=[0, 1, 2])
    prob = power.build_problem(topo, vs)
    el = PlacementSpec(max_hops=mh).masks(prob)
    hops = np.asarray(topo.path_hops)
    want = np.stack([hops[int(s)] <= mh for s in vs.src])
    np.testing.assert_array_equal(el, want)
    # unconstrained spec -> no mask at all
    assert PlacementSpec().masks(prob) is None
    # explicit eligibility ANDs on top of the hop mask
    extra = np.ones_like(want)
    extra[:, 0] = False
    both = PlacementSpec(max_hops=mh, eligible=extra).masks(prob)
    np.testing.assert_array_equal(both, want & extra)


def test_positional_constraints_rejected_by_churn(topo):
    """Sequence max_hops / explicit eligible bind to batch rows; a removal
    would shift rows and silently re-assign SLAs, so churn events refuse
    them (the static batch path still accepts them)."""
    vs = vsr.random_vsrs(2, rng=3, source_nodes=[0])
    spec = PlacementSpec(max_hops=[1, 5], method="coordinate",
                         bucket_rows=False, bucket_cols=False)
    ses = CFNSession(topo, spec)
    res = ses.solve(vs)                      # batch path: fine
    hops = np.asarray(topo.path_hops)
    for r, mh in enumerate([1, 5]):
        assert all(hops[0, p] <= mh for p in res.X[r])
    with pytest.raises(ValueError):
        ses.remove(ses.sids[0])
    with pytest.raises(ValueError):
        ses.add(vsr.random_vsrs(1, rng=9, source_nodes=[0]))
    el = np.ones((1, topo.P), bool)
    ses2 = CFNSession(topo, PlacementSpec(eligible=el))
    with pytest.raises(ValueError):
        ses2.add(vsr.random_vsrs(1, rng=9, source_nodes=[0]))


def test_spec_masks_per_service_and_padding(topo):
    """A length-n max_hops constrains the first n rows only; bucket pad
    rows beyond an explicit mask stay unconstrained."""
    vs = vsr.random_vsrs(3, rng=0, source_nodes=[0])
    prob = power.build_problem(topo, vs, pad_to_rows=4)
    el = PlacementSpec(max_hops=[1, 2, 3]).masks(prob)
    hops = np.asarray(topo.path_hops)
    for r, mh in enumerate([1, 2, 3]):
        np.testing.assert_array_equal(el[r], hops[0] <= mh)
    assert el[3].all()          # pad row unconstrained


# ---------------------------------------------------------------------------
# shim-vs-session / shim-vs-spec parity
# ---------------------------------------------------------------------------

def test_shim_embed_matches_session(topo):
    """embed() (deprecated kwargs) == CFNSession.solve under the same spec
    (coordinate is deterministic, so parity is exact)."""
    vs = vsr.random_vsrs(4, rng=11, source_nodes=[0])
    legacy = _quiet(embed.embed, topo, vs, "coordinate")
    spec = PlacementSpec(method="coordinate",
                         bucket_rows=False, bucket_cols=False)
    res = CFNSession(topo, spec).solve(vs)
    np.testing.assert_array_equal(legacy.X, res.X)
    assert legacy.power == pytest.approx(res.power, abs=1e-6)


def test_shim_solve_cfn_matches_portfolio(topo):
    """solve_cfn() (deprecated) == solve_portfolio under an equivalent
    spec: identical placement, method tag, and objective."""
    vs = vsr.random_vsrs(3, rng=5, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    legacy = _quiet(solvers.solve_cfn, prob, topo, jax.random.PRNGKey(0))
    res = solvers.solve_portfolio(prob, topo, PlacementSpec(),
                                  jax.random.PRNGKey(0))
    np.testing.assert_array_equal(legacy.X, res.X)
    assert legacy.method == res.method
    assert legacy.objective == pytest.approx(res.objective, abs=1e-6)


def test_resolve_incremental_consumes_spec(topo):
    """resolve_incremental(spec=...) == the legacy eligible= kwarg path."""
    vs = vsr.random_vsrs(4, rng=7, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    spec = PlacementSpec(max_hops=2, anneal_steps=80, anneal_chains=4)
    X0 = np.zeros((prob.R, prob.V), np.int32)
    via_spec = solvers.resolve_incremental(
        prob, X0, key=jax.random.PRNGKey(0), changed_rows=[3], spec=spec)
    el = np.asarray(topo.path_hops)[0] <= 2
    legacy = solvers.resolve_incremental(
        prob, X0, key=jax.random.PRNGKey(0), changed_rows=[3],
        anneal_steps=80, anneal_chains=4,
        eligible=np.broadcast_to(el, (prob.R, prob.P)))
    np.testing.assert_array_equal(via_spec.X, legacy.X)
    assert all(el[p] for p in via_spec.X[3])


# ---------------------------------------------------------------------------
# defrag under SLA masks (ROADMAP open-item regression)
# ---------------------------------------------------------------------------

def test_portfolio_respects_max_hops(topo):
    """The full portfolio -- the defrag path -- threads spec.masks through
    coordinate warm starts AND Metropolis proposals: no VM ever lands
    outside its service's hop radius, so the CDC (5+ hops away) is
    unreachable under a 2-hop bound."""
    vs = vsr.random_vsrs(3, rng=1, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    spec = PlacementSpec(max_hops=2)
    res = solvers.solve_portfolio(prob, topo, spec, jax.random.PRNGKey(0))
    hops = np.asarray(topo.path_hops)
    assert all(hops[0, p] <= 2 for p in res.X.reshape(-1))
    assert topo.proc_index("cdc0") not in set(res.X.reshape(-1))
    assert res.method.startswith("cfn-milp")


def test_engine_defrag_never_moves_service_out_of_radius(topo):
    """A hop-constrained service survives an explicit full-portfolio
    defrag inside its radius (the hole the spec redesign closes)."""
    make = lambda sid: vsr.random_vsrs(1, rng=40 + sid, source_nodes=[0])
    spec = PlacementSpec(max_hops=2, defrag_every=0, anneal_steps=60,
                         anneal_chains=4, polish_sweeps=1)
    ses = CFNSession(topo, spec, key=jax.random.PRNGKey(2))
    for sid in range(3):
        assert ses.add(make(sid), sid=sid) is not None
    res = ses.defrag()
    assert res is not None
    hops = np.asarray(topo.path_hops)
    for row in range(ses.n_live):
        assert all(hops[0, p] <= 2 for p in ses.X[row]), \
            (row, ses.X[row])
    # the defrag really ran a full solve against the live incumbent
    assert ses.stats[-1].event == "defrag"


# ---------------------------------------------------------------------------
# V-width bucketing (satellite): power-of-two VM columns
# ---------------------------------------------------------------------------

def test_build_problem_col_padding_is_free(topo):
    """pad_to_cols adds pinned zero-demand columns: objective, loads, and
    the free-position set are unchanged."""
    vs = vsr.random_vsrs(3, rng=2, n_vms=3, source_nodes=[0])
    prob = power.build_problem(topo, vs)
    prob_p = power.build_problem(topo, vs, pad_to_cols=4)
    assert prob.V == 3 and prob_p.V == 4
    aux, aux_p = power.build_aux(prob), power.build_aux(prob_p)
    assert aux.free_pos.shape[0] == aux_p.free_pos.shape[0]
    rng = np.random.default_rng(0)
    X = rng.integers(0, prob.P, size=(3, 3)).astype(np.int32)
    Xp = np.concatenate([X, np.zeros((3, 1), np.int32)], axis=1)
    s1 = power.init_state(prob, jnp.asarray(X))
    s2 = power.init_state(prob_p, jnp.asarray(Xp))
    assert abs(float(s1.obj) - float(s2.obj)) <= \
        1e-5 * max(1.0, abs(float(s1.obj)))
    np.testing.assert_allclose(np.asarray(s1.lam), np.asarray(s2.lam),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1.omega), np.asarray(s2.omega),
                               atol=1e-4)
    # pad columns are pinned to each row's source
    fm = np.asarray(prob_p.fixed_mask)
    fn = np.asarray(prob_p.fixed_node)
    assert fm[:, 3].all()
    np.testing.assert_array_equal(fn[:, 3], np.asarray(vs.src))


def test_engine_col_bucketing_bounds_shapes(topo):
    """Mixing 3-VM and 5-VM services keeps the problem's V on power-of-two
    buckets (one compile per bucket, not per distinct concat width), and
    the committed state still matches a from-scratch rebuild."""
    make = lambda sid, n: vsr.random_vsrs(1, rng=600 + sid, n_vms=n,
                                          source_nodes=[0])
    spec = PlacementSpec(defrag_every=0, anneal_steps=60, anneal_chains=4,
                         polish_sweeps=1)
    ses = CFNSession(topo, spec, key=jax.random.PRNGKey(4))
    shapes = set()
    for sid, n in enumerate((3, 3, 5, 4)):
        ses.add(make(sid, n), sid=sid)
        shapes.add((ses.problem.R, ses.problem.V))
    assert all((v & (v - 1)) == 0 for _, v in shapes), shapes   # pow2 V
    assert {v for _, v in shapes} <= {4, 8}, shapes
    fresh = power.init_state(ses.problem, jnp.asarray(ses.X))
    assert abs(float(fresh.obj) - ses.objective()) <= \
        1e-3 + 1e-6 * abs(float(fresh.obj))
    per = ses.attribute()
    assert abs(sum(per.values()) - ses.power_w()) <= \
        1e-6 * max(1.0, ses.power_w())
    # natural service widths are preserved for reporting
    assert [ses.service_vms(r) for r in range(4)] == [3, 3, 5, 4]


# ---------------------------------------------------------------------------
# acceptance: one churn trace, session vs legacy engine, defrag + SLA
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("max_hops", [None, 2])
def test_session_replay_matches_legacy_engine(topo, max_hops):
    """The same churn trace through CFNSession.replay and the legacy
    replay(OnlineEmbedder(kwargs...)) shim: identical placements, power,
    and admission counters (f64-oracle-checked), including defrag steps
    that respect max_hops."""
    events = dynamic.churn_trace(3, 4, rng=1)
    make = lambda sid: vsr.random_vsrs(1, rng=700 + sid, source_nodes=[0])

    eng = _quiet(dynamic.OnlineEmbedder, topo, key=jax.random.PRNGKey(7),
                 defrag_every=3, anneal_steps=60, anneal_chains=4,
                 polish_sweeps=1, max_hops=max_hops)
    legacy_stats = dynamic.replay(eng, events, make)

    spec = PlacementSpec(defrag_every=3, anneal_steps=60, anneal_chains=4,
                         polish_sweeps=1, max_hops=max_hops)
    ses = CFNSession(topo, spec, key=jax.random.PRNGKey(7))
    ses_stats = ses.replay(events, make)

    assert eng.sids == ses.sids
    np.testing.assert_array_equal(eng.X, ses.X)
    assert eng.power_w() == pytest.approx(ses.power_w(), abs=1e-9)
    assert eng.admission == ses.admission
    assert [s.event for s in legacy_stats] == [s.event for s in ses_stats]
    assert [s.method for s in legacy_stats] == [s.method for s in ses_stats]

    # the engine's reported objective is real: float64 oracle check
    want = ref.placement_objective_f64(ses.problem, ses.X)
    assert abs(ses.objective() - want) <= 5e-2 + 1e-5 * abs(want)

    # the trace crossed the defrag cadence: at least one full solve ran
    full = [s for s in legacy_stats
            if s.method.startswith(("cfn-milp", "defrag-kept"))]
    assert full, [s.method for s in legacy_stats]
    if max_hops is not None:
        hops = np.asarray(topo.path_hops)
        for row in range(ses.n_live):
            assert all(hops[0, p] <= max_hops for p in ses.X[row])
