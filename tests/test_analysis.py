"""tracelint (repro.analysis) rule-catalog tests.

Pure-AST: no jax import, no device work -- each rule gets one fixture
source with a known violation (exact rule id + line asserted) and one
clean snippet that must produce nothing.  Suppression is covered for
both channels (per-line pragma, committed baseline) plus the CLI exit
codes the CI gate relies on.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (Finding, MAX_SCALE, analyze_source,
                            apply_baseline, baseline_payload)
from repro.analysis.engine import load_baseline

REPO = Path(__file__).resolve().parents[1]


def findings_for(src, path="<string>"):
    return analyze_source(textwrap.dedent(src), path=path)


def rules_of(findings):
    return [(f.rule, f.line) for f in findings]


# ---------------------------------------------------------------------------
# CFN101: retrace hazards
# ---------------------------------------------------------------------------

def test_cfn101_item_inside_jit():
    fs = findings_for("""\
        import jax

        @jax.jit
        def solve(x):
            return x.item()
    """)
    assert ("CFN101", 5) in rules_of(fs)


def test_cfn101_float_cast_reachable_from_scan_body():
    # the hazard sits in a helper only reachable THROUGH the scan body
    fs = findings_for("""\
        import jax

        def helper(x):
            return float(x) + 1.0

        def body(carry, x):
            return carry, helper(x)

        def run(xs):
            return jax.lax.scan(body, 0.0, xs)
    """)
    assert ("CFN101", 4) in rules_of(fs)


def test_cfn101_np_asarray_inside_vmap():
    fs = findings_for("""\
        import jax
        import numpy as np

        def per_row(x):
            return np.asarray(x)

        mapped = jax.vmap(per_row)
    """)
    assert ("CFN101", 5) in rules_of(fs)


def test_cfn101_clean_static_casts_and_host_code():
    # int(x.shape[0]) / float(Constant) are static; un-jitted host code
    # may call float() freely
    fs = findings_for("""\
        import jax
        import numpy as np

        @jax.jit
        def solve(x):
            n = int(x.shape[0])
            return x * float(2.0) / n

        def host_report(res):
            return float(res), np.asarray(res)
    """)
    assert not [f for f in fs if f.rule == "CFN101"]


# ---------------------------------------------------------------------------
# CFN102: dtype discipline
# ---------------------------------------------------------------------------

def test_cfn102_float64_outside_whitelist():
    fs = findings_for("""\
        import numpy as np

        def loads(F):
            return np.zeros(4, np.float64)
    """, path="src/repro/core/newmod.py")
    assert ("CFN102", 4) in rules_of(fs)


def test_cfn102_whitelisted_oracle_path_clean():
    fs = findings_for("""\
        import numpy as np

        def eq_terms_f64(omega):
            return np.asarray(omega, np.float64)
    """, path="src/repro/kernels/ref.py")
    assert not [f for f in fs if f.rule == "CFN102"]


def test_cfn102_implicit_promotion_warns():
    fs = findings_for("""\
        import numpy as np

        def loads(F):
            return np.asarray(F, dtype=float)
    """, path="src/repro/core/newmod.py")
    hits = [f for f in fs if f.rule == "CFN102"]
    assert hits and hits[0].severity == "warning" and hits[0].line == 4


# ---------------------------------------------------------------------------
# CFN103: pytree hygiene
# ---------------------------------------------------------------------------

_PYTREE_BAD = """\
    import dataclasses
    import jax

    @jax.tree_util.register_pytree_node_class
    @dataclasses.dataclass(frozen=True)
    class Health:
        node_up: object
        link_up: object
        epoch: int

        def tree_flatten(self):
            return (self.node_up, self.link_up), None

        @classmethod
        def tree_unflatten(cls, aux, children):
            return cls(*children, epoch=0)
"""


def test_cfn103_unaccounted_field():
    fs = findings_for(_PYTREE_BAD)
    hits = [f for f in fs if f.rule == "CFN103"]
    assert hits and hits[0].line == 11 and "epoch" in hits[0].message


def test_cfn103_all_fields_accounted_clean():
    fs = findings_for(_PYTREE_BAD.replace(
        "return (self.node_up, self.link_up), None",
        "return (self.node_up, self.link_up), self.epoch"))
    assert not [f for f in fs if f.rule == "CFN103"]


def test_cfn103_degrade_must_not_change_shape():
    fs = findings_for("""\
        import jax.numpy as jnp

        def degrade(self, nodes):
            up = jnp.concatenate([self.node_up, nodes])
            return up
    """)
    assert ("CFN103", 4) in rules_of(fs)


def test_cfn103_value_only_degrade_clean():
    fs = findings_for("""\
        import jax.numpy as jnp

        def degrade(self, nodes):
            return jnp.where(nodes, False, self.node_up)
    """)
    assert not [f for f in fs if f.rule == "CFN103"]


# ---------------------------------------------------------------------------
# CFN104: trace-counter coverage (enforced in core/solvers, core/federation)
# ---------------------------------------------------------------------------

def test_cfn104_uncounted_jit_entry_in_solvers():
    fs = findings_for("""\
        import jax

        @jax.jit
        def _sweep(problem, state):
            return state
    """, path="src/repro/core/solvers.py")
    assert ("CFN104", 4) in rules_of(fs)


def test_cfn104_counted_entry_clean_and_not_enforced_elsewhere():
    counted = """\
        import jax
        from .solvers import count_traces

        @jax.jit
        @count_traces("sweep")
        def _sweep(problem, state):
            return state
    """
    fs = findings_for(counted, path="src/repro/core/solvers.py")
    assert not [f for f in fs if f.rule == "CFN104"]
    # same jit-without-counter source outside the enforced modules: clean
    fs = findings_for("""\
        import jax

        @jax.jit
        def helper(x):
            return x
    """, path="src/repro/core/power.py")
    assert not [f for f in fs if f.rule == "CFN104"]


def test_cfn104_counter_above_jit_is_flagged():
    # count_traces ABOVE jit counts calls, not traces -- distinct finding
    fs = findings_for("""\
        import jax
        from .solvers import count_traces

        @count_traces("sweep")
        @jax.jit
        def _sweep(problem, state):
            return state
    """, path="src/repro/core/solvers.py")
    hits = [f for f in fs if f.rule == "CFN104"]
    assert hits and "UNDER" in hits[0].message


# ---------------------------------------------------------------------------
# CFN105: Pallas VMEM budget
# ---------------------------------------------------------------------------

def test_cfn105_over_budget_blockspec():
    # 2048*2048 f32 = 16 MiB for ONE operand: over the 16 MiB budget
    fs = findings_for("""\
        import jax
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def launch(x):
            return pl.pallas_call(
                kernel,
                out_shape=jax.ShapeDtypeStruct((2048, 2048), x.dtype),
                in_specs=[pl.BlockSpec((2048, 2048), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((2048, 2048), lambda i: (0, 0)),
            )(x)
    """)
    hits = [f for f in fs if f.rule == "CFN105" and f.severity == "error"]
    assert hits and "VMEM" in hits[0].message


def test_cfn105_max_scale_names_resolve_and_fit():
    # P=468 rounds through the documented max scale; small K tile fits
    fs = findings_for("""\
        import jax
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def launch(x, P=468, K=14):
            return pl.pallas_call(
                kernel,
                out_shape=jax.ShapeDtypeStruct((P, K), x.dtype),
                in_specs=[pl.BlockSpec((P, K), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((P, K), lambda i: (0, 0)),
            )(x)
    """)
    assert not [f for f in fs if f.rule == "CFN105"]
    assert MAX_SCALE["P"] == 468 and MAX_SCALE["K"] == 14


def test_cfn105_python_loop_over_traced_dim_in_kernel():
    fs = findings_for("""\
        import jax
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            for i in range(x_ref.shape[0]):
                o_ref[i] = x_ref[i]

        def launch(x):
            return pl.pallas_call(
                kernel,
                out_shape=jax.ShapeDtypeStruct((8, 8), x.dtype),
                in_specs=[pl.BlockSpec((8, 8), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((8, 8), lambda i: (0, 0)),
            )(x)
    """)
    hits = [f for f in fs if f.rule == "CFN105" and f.line == 5]
    assert hits and "unroll" in hits[0].message


# ---------------------------------------------------------------------------
# suppression: pragma + baseline
# ---------------------------------------------------------------------------

def test_pragma_suppresses_same_line_and_next_line():
    src = """\
        import numpy as np

        def loads(F):
            x = np.zeros(4, np.float64)  # tracelint: allow[CFN102]
            # deliberate host accounting  # tracelint: allow[CFN102]
            y = np.zeros(4, np.float64)
            return x + y
    """
    fs = findings_for(src, path="src/repro/core/newmod.py")
    assert not [f for f in fs if f.rule == "CFN102"]
    # wrong rule id in the pragma does NOT suppress
    fs = findings_for(src.replace("allow[CFN102]", "allow[CFN101]"),
                      path="src/repro/core/newmod.py")
    assert len([f for f in fs if f.rule == "CFN102"]) == 2


def test_baseline_roundtrip_suppresses_and_survives_line_shift(tmp_path):
    src = """\
        import numpy as np

        def loads(F):
            return np.zeros(4, np.float64)
    """
    fs = findings_for(src, path="src/repro/core/newmod.py")
    payload = baseline_payload(fs)
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps(payload))
    baseline = load_baseline(str(bl))
    assert apply_baseline(fs, baseline) == []
    # shift the finding down two lines: fingerprint is line-independent
    shifted = findings_for("\n\n" + textwrap.dedent(src),
                           path="src/repro/core/newmod.py")
    assert shifted and shifted[0].line != fs[0].line
    assert apply_baseline(shifted, baseline) == []
    # a NEW violation is not covered
    fresh = [Finding(rule="CFN102", severity="error",
                     path="src/repro/core/other.py", line=1,
                     message="float64 reference `np.float64` outside the "
                             "f64 oracle whitelist")]
    assert apply_baseline(fresh, baseline) == fresh


# ---------------------------------------------------------------------------
# CLI gate: exit codes the CI job relies on
# ---------------------------------------------------------------------------

def _run_cli(args, cwd):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=str(cwd), capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})


def test_cli_shipped_tree_is_clean_with_baseline():
    r = _run_cli(["--baseline", "analysis/baseline.json", "src"], REPO)
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_seeded_violation_fails(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n\n@jax.jit\ndef f(x):\n    return x.item()\n")
    r = _run_cli(["--format", "json", str(bad)], REPO)
    assert r.returncode == 1
    out = json.loads(r.stdout)
    assert out["findings"][0]["rule"] == "CFN101"
