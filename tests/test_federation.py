"""Federation subsystem tests (the federated-fog-regions PR).

Covers: RegionPartition structure (region closure, route-table agreement
with the merged substrate, core-hop table), the acceptance criteria --
1-region federation == flat CFNSession exactly (placements AND float64
power), the 4-region batched solve under ONE vmapped compile -- exact
multi-region power conservation (regional + inter-region watts == the
float64 oracle on the equivalent flat placement, batch and churn), region
affinity under churn replay, cross-region migration on regional budget
breaches, and the fault-monitor wiring for admission/budget events.
"""
import numpy as np
import jax
import pytest

from repro.api import CFNSession, FederatedSession, PlacementSpec
from repro.core import dynamic, federation, power, solvers, topology, vsr
from repro.fault.monitor import PlacementMonitor
from repro.kernels import ref as kref


def _fed_topo(n_regions=3, n_core=6):
    """A small federated substrate: P_r = 7 per region."""
    return topology.federated_scale(n_regions=n_regions, n_olt=1,
                                    onus_per_olt=2, iot_per_onu=2,
                                    n_core=n_core)


@pytest.fixture(scope="module")
def ftopo():
    return _fed_topo()


@pytest.fixture(scope="module")
def fpart(ftopo):
    return federation.RegionPartition.from_topology(ftopo)


def _region_sources(part):
    return [int(r.proc_ids[0]) for r in part.regions]


def _oracle_gap(topo, vsrs, X, objective):
    prob = power.build_problem(topo, vsrs)
    X = np.asarray(X)[:vsrs.R, :vsrs.V]   # strip bucket padding
    oracle = kref.placement_objective_f64(prob, X)
    return abs(oracle - objective), oracle


# ---------------------------------------------------------------------------
# partition structure
# ---------------------------------------------------------------------------

def test_partition_structure(ftopo, fpart):
    assert fpart.G == 3
    # every processing node in exactly one region; shared core unassigned
    assert sorted(np.concatenate([r.proc_ids for r in fpart.regions])
                  .tolist()) == list(range(ftopo.P))
    assert len(fpart.core_net_ids) == 6
    assert all(ftopo.net_names[n].startswith("nsf")
               for n in fpart.core_net_ids)
    # core-hop table: symmetric, zero diagonal, positive off-diagonal
    assert np.array_equal(fpart.core_hops, fpart.core_hops.T)
    assert np.all(np.diag(fpart.core_hops) == 0)
    off = fpart.core_hops[~np.eye(fpart.G, dtype=bool)]
    assert np.all(off > 0)


def test_region_routes_match_merged(ftopo, fpart):
    """Each region's own route table == the merged table restricted to the
    region (ids remapped) -- the closure property the exact decomposition
    rests on."""
    rt_merged = np.asarray(ftopo.route_idx)
    for reg in fpart.regions:
        lut = np.full(ftopo.N + 1, reg.N, np.int64)
        lut[reg.net_ids] = np.arange(reg.N)
        mapped = lut[rt_merged[np.ix_(reg.proc_ids, reg.proc_ids)]]
        local = np.asarray(reg.topo.route_idx)
        K = max(mapped.shape[2], local.shape[2])
        pad = lambda a: np.concatenate(
            [a, np.full(a.shape[:2] + (K - a.shape[2],), reg.N, a.dtype)],
            axis=2)
        np.testing.assert_array_equal(pad(mapped), pad(local))


def test_partition_single_identity(ftopo):
    part = federation.RegionPartition.single(ftopo)
    assert part.G == 1
    assert part.regions[0].topo is ftopo
    np.testing.assert_array_equal(part.regions[0].proc_ids,
                                  np.arange(ftopo.P))


# ---------------------------------------------------------------------------
# acceptance: 1-region federation == flat session, exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 3])
def test_single_region_parity_exact(seed):
    """A federation of one region reproduces the flat CFNSession.solve()
    placements and float64-oracle power EXACTLY (gap 0)."""
    topo = topology.paper_topology()
    vs = vsr.random_vsrs(5, rng=seed, source_nodes=[0])
    spec = PlacementSpec(effort="quick")
    flat = CFNSession(topo, spec)
    fed = FederatedSession(topo, spec)
    rf = flat.solve(vs)
    rr = fed.solve(vs)
    np.testing.assert_array_equal(rf.X, rr.X)
    gap_f, oracle_f = _oracle_gap(topo, vs, rf.X, 0.0)
    gap_r, oracle_r = _oracle_gap(topo, vs, rr.X, 0.0)
    assert oracle_f == oracle_r                       # f64 gap is exactly 0
    # the federated breakdown on the delegated state matches the oracle
    bd = fed.breakdown()
    assert bd.objective == oracle_r
    assert bd.regional_w.shape == (1,)
    assert bd.inter_region_w == 0.0


# ---------------------------------------------------------------------------
# acceptance: multi-region conservation + single vmapped compile
# ---------------------------------------------------------------------------

def test_multi_region_conservation(ftopo, fpart):
    """Sum of regional + inter-region watts == the float64 oracle on the
    equivalent flat placement, with cross-region services in play."""
    srcs = _region_sources(fpart)
    vs = vsr.random_vsrs(6, rng=1, source_nodes=srcs)
    homes = [fpart.home_region(int(s)) for s in vs.src]
    aff = np.full(6, -1)
    aff[0] = (homes[0] + 1) % 3        # force two cross-region services
    aff[1] = (homes[1] + 2) % 3
    spec = PlacementSpec(effort="quick", region_affinity=aff)
    sess = FederatedSession(ftopo, spec)
    res = sess.solve(vs)
    bd = res.breakdown
    # identity: regional + inter == total (by construction, still pinned)
    assert abs(bd.regional_w.sum() + bd.inter_region_w
               - bd.total_w) <= 1e-9 * max(1.0, bd.total_w)
    # exactness: the decomposed evaluation equals a from-scratch f64 oracle
    gap, oracle = _oracle_gap(ftopo, vs, res.X, bd.objective)
    assert gap <= 1e-7 * max(1.0, abs(oracle))
    # the affinity-forced services really are cross-region and priced
    assert res.assignments[0] == aff[0] and res.assignments[1] == aff[1]
    assert bd.inter_region_w > 0.0
    # the session's post-seed (engine-backed) accounting agrees too
    gap2 = abs(sess.breakdown().objective - oracle)
    assert gap2 <= 1e-7 * max(1.0, abs(oracle))


def test_four_region_single_vmapped_compile():
    """Acceptance: a 4-region federated_scale solve runs every per-region
    portfolio under ONE vmapped trace (same shape bucket across regions)."""
    topo = _fed_topo(n_regions=4, n_core=4)
    part = federation.RegionPartition.from_topology(topo)
    # one shape bucket: all regions pad to identical (P, N, K)
    subs, masks, (P_pad, N_pad, K_pad) = part.padded_substrates()
    for d in subs:
        assert d["route_idx"].shape == (P_pad, P_pad, K_pad)
        assert d["E"].shape == (P_pad,)
    vs = vsr.random_vsrs(8, rng=0, source_nodes=_region_sources(part))
    sess = FederatedSession(topo, PlacementSpec(effort="quick"))
    before = solvers.TRACE_COUNTS.get("solve_regions", 0)
    res = sess.solve(vs)
    assert solvers.TRACE_COUNTS.get("solve_regions", 0) == before + 1
    # placements landed on real (non-pad) nodes of the right regions
    for i, g in enumerate(res.assignments):
        reg = part.regions[g]
        free = ~np.asarray([v == int(vs.input_vm[i])
                            for v in range(vs.V)])
        assert np.isin(res.X[i][free], reg.proc_ids).all()
    # a second same-bucket federation (same service distribution, fresh
    # demands) re-uses the compiled program
    vs2 = vsr.random_vsrs(8, rng=5, source_nodes=_region_sources(part))
    vs2.src[:] = vs.src          # same homes -> same shape bucket
    sess2 = FederatedSession(topo, PlacementSpec(effort="quick"))
    sess2.solve(vs2)
    assert solvers.TRACE_COUNTS.get("solve_regions", 0) == before + 1


# ---------------------------------------------------------------------------
# churn: affinity, conservation, budgets, monitor
# ---------------------------------------------------------------------------

def test_region_affinity_never_violated_under_churn(ftopo, fpart):
    """Scalar region_affinity pins every service's free VMs to the target
    region through a whole churn replay (arrivals AND departures)."""
    target = 1
    spec = PlacementSpec(effort="quick", region_affinity=target,
                         defrag_every=0, anneal_steps=100)
    sess = FederatedSession(ftopo, spec)
    srcs = _region_sources(fpart)
    make = lambda sid: vsr.random_vsrs(1, rng=100 + sid,
                                       source_nodes=[srcs[sid % 3]])
    events = [dynamic.ServiceEvent(float(t), "arrive", t) for t in range(4)]
    events += [dynamic.ServiceEvent(5.0, "depart", 1),
               dynamic.ServiceEvent(6.0, "arrive", 9),
               dynamic.ServiceEvent(7.0, "depart", 0)]
    reg = fpart.regions[target]

    def check(ev, res):
        X = sess.X
        for row, sid in enumerate(sess.sids):
            assert sess.assignment(sid) == target
            plan = sess._plans[sid]
            iv = int(plan.vsr.input_vm[0])
            V = plan.vsr.V
            for v in range(V):
                if v == iv:
                    continue
                assert X[row, v] in reg.proc_ids, (sid, v, X[row, v])

    sess.replay(events, make, on_event=check)
    assert sess.n_live == 3


def test_online_churn_conservation(ftopo, fpart):
    """After every add/remove the exact federated accounting equals the
    float64 oracle of the merged live placement."""
    srcs = _region_sources(fpart)
    spec = PlacementSpec(effort="quick", defrag_every=0, anneal_steps=100)
    sess = FederatedSession(ftopo, spec)
    live = {}
    for i in range(3):
        s = vsr.random_vsrs(1, rng=20 + i, source_nodes=[srcs[i % 3]])
        assert sess.add(s, sid=i) is not None
        live[i] = s
    sess.remove(1)
    del live[1]
    s = vsr.random_vsrs(1, rng=40, source_nodes=[srcs[1]])
    sess.add(s, sid=7)
    live[7] = s
    batch = None
    for sid in sess.sids:
        batch = live[sid] if batch is None else batch.concat(live[sid])
    bd = sess.breakdown()
    gap, oracle = _oracle_gap(ftopo, batch, sess.X, bd.objective)
    assert gap <= 1e-7 * max(1.0, abs(oracle))
    assert abs(bd.regional_w.sum() + bd.inter_region_w - bd.total_w) \
        <= 1e-9 * max(1.0, bd.total_w)


def test_budget_breach_migrates_and_counts():
    """An arrival pushing its region past region_power_budget_w is migrated
    to the coolest admissible region; breach + migration hit the monitor."""
    topo = _fed_topo(n_regions=2, n_core=4)
    part = federation.RegionPartition.from_topology(topo)
    mon = PlacementMonitor()
    spec = PlacementSpec(effort="quick", region_power_budget_w=[180.0, 1e9],
                         defrag_every=0, anneal_steps=100)
    sess = FederatedSession(topo, spec, monitor=mon)
    src0 = int(part.regions[0].proc_ids[0])
    assigned = []
    for i in range(4):
        res = sess.add(vsr.random_vsrs(1, rng=i, source_nodes=[src0]))
        assert res is not None
        assigned.append(sess.assignment(i))
    assert assigned[-1] == 1, assigned          # migrated off region 0
    assert mon.get("region_budget_breach") >= 1
    assert mon.get("cross_region_migration") >= 1
    # the migrated service is priced over the core
    assert sess.breakdown().inter_region_w > 0.0
    # and the migrated body keeps its pinned source at home
    plan = sess._plans[3]
    assert plan.migrated and plan.home == 0 and plan.assigned == 1
    assert sess.X[3, int(plan.vsr.input_vm[0])] == src0


def test_batch_coordinator_migrates_on_budget():
    """The batch-path coordinator migrates services out of an over-budget
    region, re-solves after every move, and the result stays exactly
    conserved with the cut links priced over the core."""
    topo = _fed_topo(n_regions=2, n_core=4)
    part = federation.RegionPartition.from_topology(topo)
    src0 = int(part.regions[0].proc_ids[0])
    vs = vsr.random_vsrs(5, rng=0, source_nodes=[src0])   # all homed in r0
    mon = PlacementMonitor()
    spec = PlacementSpec(effort="quick",
                         region_power_budget_w=[150.0, 1e9])
    sess = FederatedSession(topo, spec, monitor=mon)
    res = sess.solve(vs)
    assert res.migrations >= 1
    assert (res.assignments == 1).sum() == res.migrations
    assert mon.get("cross_region_migration") == res.migrations
    assert res.breakdown.inter_region_w > 0.0
    gap, oracle = _oracle_gap(topo, vs, res.X, res.breakdown.objective)
    assert gap <= 1e-7 * max(1.0, abs(oracle))


def test_single_vm_services_solve(ftopo, fpart):
    """All-pinned workloads (V=1 services: input VM only) solve on the
    batched path instead of tripping the no-free-position guard."""
    srcs = _region_sources(fpart)
    vs = vsr.VSRBatch(F=np.full((3, 1), 0.4, np.float32),
                      H=np.zeros((3, 1, 1), np.float32),
                      src=np.asarray(srcs, np.int32),
                      input_vm=np.zeros(3, np.int32))
    sess = FederatedSession(ftopo, PlacementSpec(effort="quick"))
    res = sess.solve(vs)
    np.testing.assert_array_equal(res.X[:, 0], np.asarray(srcs))
    gap, oracle = _oracle_gap(ftopo, vs, res.X, res.breakdown.objective)
    assert gap <= 1e-7 * max(1.0, abs(oracle))


def test_attribute_sums_to_total_with_migrations(ftopo, fpart):
    """Per-tenant watts sum to the exact fleet total even when cut links
    put watts on regional egress/ingress nodes no engine sees."""
    srcs = _region_sources(fpart)
    spec = PlacementSpec(effort="quick", anneal_steps=100, defrag_every=0)
    sess = FederatedSession(ftopo, spec)
    for i in range(3):
        sess.add(vsr.random_vsrs(1, rng=30 + i, source_nodes=[srcs[0]]),
                 sid=i, region=i)          # two of three are cross-region
    per = sess.attribute()
    bd = sess.breakdown()
    assert bd.inter_region_w > 0.0
    assert abs(sum(per.values()) - bd.total_w) <= 1e-6 * bd.total_w


def test_churn_respects_inter_region_hop_cap(ftopo, fpart):
    """add(region=) / scalar affinity validate inter_region_hops exactly
    like the batch path's _assign."""
    srcs = _region_sources(fpart)
    far = int(fpart.core_hops[0].max())
    spec = PlacementSpec(effort="quick", inter_region_hops=far - 1,
                         anneal_steps=100)
    sess = FederatedSession(ftopo, spec)
    over = int(np.argmax(fpart.core_hops[0]))
    with pytest.raises(ValueError, match="inter_region_hops"):
        sess.add(vsr.random_vsrs(1, rng=0, source_nodes=[srcs[0]]),
                 region=over)


def test_monitor_counts_admission_rejections():
    """OnlineEmbedder reports admission rejections (and names the violated
    budget) on the attached monitor instead of dropping them."""
    topo = topology.paper_topology()
    mon = PlacementMonitor()
    spec = PlacementSpec(power_budget_w=1e-6, effort="quick",
                         anneal_steps=50)
    sess = CFNSession(topo, spec, monitor=mon)
    s0 = vsr.random_vsrs(1, rng=0, source_nodes=[0])
    s1 = vsr.random_vsrs(1, rng=1, source_nodes=[0])
    assert sess.add(s0) is None              # even the first add draws power
    assert sess.add(s1) is None
    assert mon.get("admission_rejected") == 2
    assert mon.get("power_budget_exceeded") == 2
    assert sess.admission["rejected"] == 2


def test_spec_rejects_row_positional_for_federation(ftopo):
    with pytest.raises(ValueError):
        FederatedSession(ftopo, PlacementSpec(max_hops=[1, 2, 3]))


def test_add_explicit_region_and_sequence_guard(ftopo, fpart):
    """add(region=) pins the host region; sequence region_affinity is
    refused on the churn path (it binds to batch rows)."""
    spec = PlacementSpec(effort="quick", anneal_steps=100, defrag_every=0)
    sess = FederatedSession(ftopo, spec)
    svc = vsr.random_vsrs(1, rng=0, source_nodes=[_region_sources(fpart)[0]])
    assert sess.add(svc, sid=0, region=2) is not None
    assert sess.assignment(0) == 2
    assert sess._plans[0].migrated    # homed in 0, hosted in 2
    seq = PlacementSpec(effort="quick", region_affinity=[1, 2])
    sess2 = FederatedSession(ftopo, seq)
    with pytest.raises(ValueError, match="sequence region_affinity"):
        sess2.add(vsr.random_vsrs(1, rng=1,
                                  source_nodes=[_region_sources(fpart)[0]]))


def test_scheduler_drives_federated_session(ftopo, fpart):
    """EnergyAwareScheduler schedules inference services onto a federation
    through the session= escape hatch: placements stay in each service's
    home region and per-tenant watts report."""
    from repro.serve.scheduler import EnergyAwareScheduler, Service
    from repro.configs.h2o_danube_3_4b import CONFIG as ARCH
    spec = PlacementSpec(effort="quick", anneal_steps=100, defrag_every=0)
    sess = FederatedSession(ftopo, spec)
    sched = EnergyAwareScheduler(ftopo, session=sess)
    srcs = _region_sources(fpart)
    sched.add_service(Service("svc-a", ARCH, tokens_per_s=5.0, n_stages=2,
                              source_node=srcs[0]))
    pls = sched.add_service(Service("svc-b", ARCH, tokens_per_s=5.0,
                                    n_stages=2, source_node=srcs[1]))
    assert [p.service for p in pls] == ["svc-a", "svc-b"]
    for p, g in zip(pls, (0, 1)):
        names = set(fpart.regions[g].topo.proc_names)
        assert all(n in names for n in p.stage_nodes)
    assert sched.total_power_w() > 0
    sched.remove_service("svc-a")
    assert [p.service for p in sched.placements()] == ["svc-b"]


# ---------------------------------------------------------------------------
# slow smoke: the default 4x16-node federation end to end
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_federated_scale_smoke():
    """Default federated_scale (4 regions, P=64) batch solve + churn:
    feasible, conserved, single-compile."""
    topo = topology.federated_scale()
    part = federation.RegionPartition.from_topology(topo)
    assert topo.P == 64 and part.G == 4
    srcs = _region_sources(part)
    vs = vsr.random_vsrs(12, rng=0, source_nodes=srcs)
    spec = PlacementSpec(effort="quick", anneal_steps=150)
    sess = FederatedSession(topo, spec)
    before = solvers.TRACE_COUNTS.get("solve_regions", 0)
    res = sess.solve(vs)
    assert solvers.TRACE_COUNTS.get("solve_regions", 0) == before + 1
    assert res.breakdown.violation <= 1e-6
    gap, oracle = _oracle_gap(topo, vs, res.X, res.breakdown.objective)
    assert gap <= 1e-7 * max(1.0, abs(oracle))
    # churn on top of the batch seed
    extra = vsr.random_vsrs(1, rng=77, source_nodes=[srcs[2]])
    assert sess.add(extra) is not None
    sess.remove(3)
    assert sess.n_live == 12
    bd = sess.breakdown()
    assert abs(bd.regional_w.sum() + bd.inter_region_w - bd.total_w) \
        <= 1e-9 * max(1.0, bd.total_w)
