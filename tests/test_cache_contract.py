"""CFN108 runtime contract: static jit-cache bounds vs measured traces.

``repro.analysis.compute_cache_bounds`` claims a static bound on the
jit-cache key-space of every ``@count_traces`` entry.  These tests replay
real scenarios and cross-check the claim against ``solvers.TRACE_COUNTS``:
for each exercised entry the measured compile count must satisfy

    measured <= bound(scenario) <= 2 * measured

i.e. the static bound is sound (never undercounts) and tight (within 2x
of reality).  Scenario bounds come from ``EntryBound.evaluate`` with the
realized axis cardinalities (the number of shape buckets the trace
actually produced); unexercised call sites are excluded by context.

Shape hygiene: both scenarios use service shapes (``n_vms``) no other
test uses, so the jit cache cannot have been pre-warmed by another test
in the same process and the measured deltas are true compile counts.
"""
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.analysis import compute_cache_bounds
from repro.analysis.engine import load_project
from repro.api import FederatedSession, PlacementSpec
from repro.core import federation, power, solvers, topology, vsr

REPO = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def bounds():
    project, errs = load_project([str(REPO / "src")])
    assert not errs
    return compute_cache_bounds(project)


def _deltas(before):
    return {k: solvers.TRACE_COUNTS.get(k, 0) - before.get(k, 0)
            for k in set(solvers.TRACE_COUNTS) | set(before)}


def _check(entry, measured, bound):
    assert bound is not None, f"{entry}: scenario bound is unbounded"
    assert measured <= bound, \
        f"{entry}: measured {measured} traces > static bound {bound}"
    assert bound <= 2 * measured, \
        f"{entry}: static bound {bound} not within 2x of measured {measured}"


def test_churn_wave_traces_within_static_bounds(bounds):
    """A two-bucket churn trace through ``resolve_wave``: the realized
    ``sweep`` / ``anneal_delta`` trace counts sit inside the CFN108
    scenario bounds for the ``resolve_incremental`` call sites."""
    topo = topology.paper_topology()
    # n_vms=5 is unique to this test: every compile below is fresh
    vs = vsr.random_vsrs(6, rng=0, n_vms=5,
                         source_nodes=topo.layer_indices("iot")[:3])
    problem = power.build_problem(topo, vs)
    X0 = np.asarray(solvers.fixed_layer(problem, topo, "iot").X, np.int32)
    state = power.init_state(problem, X0)
    key = jax.random.PRNGKey(0)
    kw = dict(anneal_steps=50, anneal_chains=4)

    waves = [[0], [1, 2, 3]]            # two distinct wave-shape buckets
    realized = set()
    for rows in waves:
        fixed = np.asarray(problem.fixed_mask)[rows]
        realized.add(solvers._pow2(int((~fixed).sum())))
    assert len(realized) == 2, "scenario must span two buckets"

    before = dict(solvers.TRACE_COUNTS)
    for rows in waves:
        solvers.resolve_wave(problem, state, rows, key=key, **kw)
    d = _deltas(before)

    cards = {"resolve_incremental.pad_changed_to": len(realized),
             # polish pads to one fixed all-free-VM list per problem shape
             "resolve_incremental.pad_positions_to": 1}
    for entry in ("sweep", "anneal_delta"):
        bound = bounds[entry].evaluate(sites=["resolve_incremental"],
                                       axis_cards=cards)
        _check(entry, d.get(entry, 0), bound)


def test_federated_solve_regions_within_static_bound(bounds):
    """Two same-bucket federated solves compile ``solve_regions`` once;
    the CFN108 scenario bound for the ``solve_portfolio_batched`` site
    (one substrate bucket, one effort tier) agrees within 2x."""
    topo = topology.federated_scale(n_regions=3, n_olt=1, onus_per_olt=2,
                                    iot_per_onu=2, n_core=6)
    part = federation.RegionPartition.from_topology(topo)
    srcs = [int(r.proc_ids[0]) for r in part.regions]
    # n_vms=4 with this R is unique to this test (fresh compiles)
    vs1 = vsr.random_vsrs(6, rng=0, n_vms=4, source_nodes=srcs)
    vs2 = vsr.random_vsrs(6, rng=5, n_vms=4, source_nodes=srcs)
    vs2.src[:] = vs1.src                # same homes -> same shape bucket
    spec = PlacementSpec(effort="quick")

    before = dict(solvers.TRACE_COUNTS)
    FederatedSession(topo, spec).solve(vs1)
    FederatedSession(topo, spec).solve(vs2)
    d = _deltas(before)

    eb = bounds["solve_regions"]
    cards = {name: 1 for name, ax in eb.axes().items()
             if ax.kind in ("bucket", "finite")}   # one bucket, one effort
    bound = eb.evaluate(sites=["solve_portfolio_batched"], axis_cards=cards)
    _check("solve_regions", d.get("solve_regions", 0), bound)


def test_telemetry_attribution_matches_trace_counts(bounds):
    """The telemetry compile-attribution hook records EXACTLY the traces
    ``TRACE_COUNTS`` ticks during a live scenario, and every recorded
    entry stays within its CFN108 static bound (``tel.report(bounds=)``)."""
    from repro.telemetry import Telemetry

    topo = topology.paper_topology()
    # n_vms=6 is unique to this test: compiles below are fresh, so the
    # hook (attached only here) must see every one of them
    vs = vsr.random_vsrs(5, rng=2, n_vms=6,
                         source_nodes=topo.layer_indices("iot")[:3])
    problem = power.build_problem(topo, vs)
    X0 = np.asarray(solvers.fixed_layer(problem, topo, "iot").X, np.int32)
    state = power.init_state(problem, X0)

    tel = Telemetry()
    tel.attach_traces()
    before = dict(solvers.TRACE_COUNTS)
    solvers.resolve_wave(problem, state, [0, 1], key=jax.random.PRNGKey(0),
                         anneal_steps=50, anneal_chains=4)
    measured = {k: v for k, v in _deltas(before).items() if v}
    rep = tel.report(bounds=bounds)
    tel.close()

    assert rep["compiles"]["agree"] is True
    assert rep["compiles"]["recorded"] == measured
    assert measured, "scenario must compile something fresh"
    for entry, chk in rep["compiles"]["bounds"].items():
        assert chk["within"], \
            f"{entry}: recorded compiles exceed CFN108 static bound " \
            f"{chk['static_bound']}"
