"""Hypothesis compatibility shim: re-exports the real library when it is
installed, otherwise provides a minimal deterministic fallback so the test
suite still collects and runs on a clean environment (the property tests
then run a fixed number of seeded pseudo-random examples instead of
hypothesis' adaptive search).

Usage in tests:  ``from _hyp import given, settings, st``
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # fallback: seeded example sweep
    import numpy as _np

    HAVE_HYPOTHESIS = False

    class _Integers:
        def __init__(self, min_value: int, max_value: int):
            self.min_value = min_value
            self.max_value = max_value

        def sample(self, rng: "_np.random.Generator") -> int:
            return int(rng.integers(self.min_value, self.max_value + 1))

    class _Floats:
        def __init__(self, min_value: float, max_value: float):
            self.min_value = min_value
            self.max_value = max_value

        def sample(self, rng: "_np.random.Generator") -> float:
            return float(rng.uniform(self.min_value, self.max_value))

    class _Just:
        def __init__(self, value):
            self.value = value

        def sample(self, rng):
            return self.value

    class _SampledFrom:
        def __init__(self, options):
            self.options = list(options)

        def sample(self, rng):
            return self.options[int(rng.integers(0, len(self.options)))]

    class _OneOf:
        def __init__(self, strategies):
            self.strategies = strategies

        def sample(self, rng):
            k = int(rng.integers(0, len(self.strategies)))
            return self.strategies[k].sample(rng)

    class st:  # noqa: N801 - mimics `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Integers:
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Floats:
            return _Floats(min_value, max_value)

        @staticmethod
        def none() -> _Just:
            return _Just(None)

        @staticmethod
        def booleans() -> _SampledFrom:
            return _SampledFrom([False, True])

        @staticmethod
        def sampled_from(options) -> _SampledFrom:
            return _SampledFrom(options)

        @staticmethod
        def one_of(*strategies) -> _OneOf:
            return _OneOf(strategies)

    def settings(**kwargs):
        def deco(fn):
            fn._hyp_settings = kwargs
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # The wrapper takes NO parameters: pytest must not mistake the
            # strategy arguments for fixtures.
            def wrapper():
                conf = getattr(wrapper, "_hyp_settings", {})
                n = conf.get("max_examples", 10)
                rng = _np.random.default_rng(0)
                for _ in range(n):
                    fn(**{name: s.sample(rng)
                          for name, s in strategies.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
