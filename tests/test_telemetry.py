"""Unified telemetry plane tests.

The contract under test: telemetry OBSERVES the serving stack, it never
perturbs it.  Disabled (telemetry=None) the engine must be bit-identical
to a never-instrumented one -- same placements, same power, same
admission decisions, zero extra compiles -- and enabled it must record
faithfully: span nesting and exception safety, histogram bucket edges,
JSONL round-trips through the report pipeline, the energy ledger summing
exactly to the per-tenant/per-region attribution, the monitor mirror
staying in lockstep with the standalone counters, and the compile
attribution agreeing with ``solvers.TRACE_COUNTS``.  The package itself
must lint clean under tracelint with an empty baseline.
"""
import json
import math
from pathlib import Path

import numpy as np
import pytest

from repro.api import CFNSession, FederatedSession, PlacementSpec
from repro.core import dynamic, federation, power, solvers, topology, vsr
from repro.fault.monitor import PlacementMonitor
from repro.kernels import ref as kref
from repro.telemetry import (EnergyLedger, Telemetry, load_events,
                             summarize_events, tiers_of, validate_events)
from repro.telemetry.registry import _bucket_edge

REPO = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def topo():
    return topology.paper_topology()


def _svc(seed, n_vms=3):
    return vsr.random_vsrs(1, rng=np.random.default_rng(seed),
                           n_vms=n_vms)


def _spec(**kw):
    return PlacementSpec(method="anneal", effort="quick", **kw)


def _churn(sess):
    """A small deterministic churn sequence: 3 adds, 1 remove, 1 wave."""
    for seed in (0, 1, 2):
        sess.engine.tick(float(seed))
        sess.add(_svc(seed))
    sess.engine.tick(3.0)
    sess.remove(sess.sids[0])
    sess.engine.tick(4.0)
    sess.apply_wave([(_svc(7), None, 0), (_svc(8), None, 0)],
                    [sess.sids[0]])
    return sess


# ---------------------------------------------------------------------------
# disabled path: a no-op by construction
# ---------------------------------------------------------------------------

def test_disabled_telemetry_is_identical(topo, tmp_path):
    """telemetry=None vs a live Telemetry: same placements (bitwise),
    same f64-oracle power, same admission outcomes, and the instrumented
    run adds ZERO fresh solver compiles beyond the baseline run's."""
    plain = _churn(CFNSession(topo, _spec(), telemetry=None))
    before = dict(solvers.TRACE_COUNTS)
    tel = Telemetry(jsonl_path=str(tmp_path / "run.jsonl"))
    instr = _churn(CFNSession(topo, _spec(), telemetry=tel))
    fresh = {k: solvers.TRACE_COUNTS.get(k, 0) - before.get(k, 0)
             for k in solvers.TRACE_COUNTS
             if solvers.TRACE_COUNTS.get(k, 0) != before.get(k, 0)}
    assert not fresh, \
        f"instrumented replay of an identical scenario retraced: {fresh}"

    assert plain.sids == instr.sids          # same admissions, same order
    Xp, Xi = np.asarray(plain.X), np.asarray(instr.X)
    assert np.array_equal(Xp, Xi)
    assert plain.power_w() == instr.power_w()

    # pin the power both engines agree on against the f64 oracle
    eng = instr.engine
    vs = eng._vsrs[0]
    for b in eng._vsrs[1:]:
        vs = vs.concat(b)
    prob = power.build_problem(topo, vs)
    oracle = kref.placement_objective_f64(prob, Xi[:vs.R, :vs.V])
    assert instr.power_w() == pytest.approx(oracle, rel=1e-5)
    tel.close()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_nesting_parents():
    tel = Telemetry()
    with tel.span("outer") as so:
        with tel.span("inner") as si:
            assert si.parent == so.id
        with tel.span("inner") as s2:
            assert s2.parent == so.id
    assert so.parent is None
    assert not tel._span_stack
    assert tel.counters["span.outer"] == 1
    assert tel.counters["span.inner"] == 2
    evs = [e for e in tel.events if e["type"] == "span"]
    by_id = {e["id"]: e for e in evs}
    inner = [e for e in evs if e["name"] == "inner"]
    assert all(by_id[e["parent"]]["name"] == "outer" for e in inner)


def test_span_exception_safe():
    tel = Telemetry()
    with pytest.raises(ValueError):
        with tel.span("boom"):
            raise ValueError("no")
    assert not tel._span_stack               # stack popped
    ev = [e for e in tel.events if e["type"] == "span"][-1]
    assert ev["ok"] is False and ev["err"] == "ValueError"
    assert tel.hists["span.boom.ms"].count == 1   # duration still recorded


def test_span_sync_blocks_on_value():
    jax = pytest.importorskip("jax")
    tel = Telemetry()
    with tel.span("device") as sp:
        out = sp.sync(jax.numpy.arange(8) * 2)
    assert int(out[-1]) == 14
    assert tel.hists["span.device.ms"].count == 1


# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------

def test_histogram_bucket_edges():
    # exact powers of two land on their own edge; everything else rounds
    # up to the next power of two; non-positive values pool at 0
    assert _bucket_edge(1.0) == 1.0
    assert _bucket_edge(1.5) == 2.0
    assert _bucket_edge(2.0) == 2.0
    assert _bucket_edge(2.1) == 4.0
    assert _bucket_edge(0.75) == 1.0
    assert _bucket_edge(0.5) == 0.5
    assert _bucket_edge(0.0) == 0.0
    assert _bucket_edge(-3.0) == 0.0
    for v in (1e-6, 0.3, 7.0, 1234.5):
        e = _bucket_edge(v)
        assert v <= e < 2 * v
        m, _ = math.frexp(e)
        assert m == 0.5                       # an exact power of two


def test_histogram_stats_and_prometheus():
    tel = Telemetry()
    for v in (1.0, 1.5, 2.0, 2.1, 100.0):
        tel.observe("lat.ms", v)
    h = tel.hists["lat.ms"]
    assert h.count == 5 and h.min == 1.0 and h.max == 100.0
    assert h.sum == pytest.approx(106.6)
    assert h.buckets == {1.0: 1, 2.0: 2, 4.0: 1, 128.0: 1}
    text = tel.prometheus()
    assert 'repro_lat_ms_bucket{le="+Inf"} 5' in text
    assert "repro_lat_ms_count 5" in text
    # le buckets are cumulative
    assert 'repro_lat_ms_bucket{le="2.0"} 3' in text


def test_metric_labels_flatten_sorted():
    tel = Telemetry()
    tel.inc("waves", b="y", a=1)
    tel.inc("waves", a=1, b="y")
    assert tel.counters == {"waves{a=1,b=y}": 2}


# ---------------------------------------------------------------------------
# JSONL round-trip
# ---------------------------------------------------------------------------

def test_jsonl_roundtrip(tmp_path):
    path = tmp_path / "run.jsonl"
    with Telemetry(jsonl_path=str(path)) as tel:
        with tel.span("work", r_bucket=4):
            tel.inc("things")
        tel.ledger.tick(0.0, total_w=10.0, net_w=4.0, proc_w=6.0)
        tel.ledger.tick(2.0, total_w=20.0, net_w=8.0, proc_w=12.0)
        tel.emit("event", kind="node_failed", detail="p3", n=1)
    evs = load_events(str(path))
    assert validate_events(evs) == []
    assert evs[0]["type"] == "meta" and evs[0]["version"] == 1
    assert evs[-1]["type"] == "summary"
    s = summarize_events(evs)
    assert s["spans"]["work"]["count"] == 1
    # left-hold: 10 W held for 2 h = 72 kJ, final sample extends nothing
    assert s["energy"]["joules_total"] == pytest.approx(10.0 * 2 * 3600)
    assert s["energy"]["joules_net"] == pytest.approx(4.0 * 2 * 3600)
    assert s["monitor"] == {"node_failed": 1}


def test_load_events_rejects_bad_lines(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"type": "meta", "ts": 0, "version": 1}\nnot json\n')
    with pytest.raises(ValueError):
        load_events(str(path))


def test_validate_flags_missing_fields():
    assert validate_events([{"type": "span", "ts": 1.0}])  # no name/dur
    assert validate_events([{"ts": 1.0}])                  # no type


# ---------------------------------------------------------------------------
# energy ledger
# ---------------------------------------------------------------------------

def test_ledger_integration_left_hold():
    led = EnergyLedger()
    led.tick(0.0, total_w=100.0, net_w=40.0, proc_w=60.0)
    led.tick(1.0, total_w=50.0, net_w=20.0, proc_w=30.0)
    out = led.integrate(t_end=3.0)
    # 100 W for 1 h + 50 W for 2 h = 200 Wh = 720 kJ
    assert out["joules_total"] == pytest.approx(200.0 * 3600)
    assert out["joules_net"] == pytest.approx(80.0 * 3600)
    assert out["joules_proc"] == pytest.approx(120.0 * 3600)
    assert out["joules_net"] + out["joules_proc"] == \
        pytest.approx(out["joules_total"])


def test_ledger_tenant_attribution_exact(topo):
    """With per-commit attribution, every sample's tenant watts sum to
    the sample total EXACTLY (attribute_power's conservation), and the
    integrated per-tenant joules sum to the total joules."""
    tel = Telemetry(attribution_every=1)
    sess = _churn(CFNSession(topo, _spec(), telemetry=tel))
    assert tel.ledger.samples
    for s in tel.ledger.samples:
        assert "tenant_w" in s
        # tenant split is f64-exact; the sample total is the engine's f32
        # breakdown, so they agree to f32 precision
        assert sum(s["tenant_w"].values()) == pytest.approx(
            s["total_w"], rel=1e-6)
        assert s["net_w"] + s["proc_w"] == pytest.approx(
            s["total_w"], rel=1e-6)
    # cross-check the LAST sample against a fresh attribute_power call
    eng = sess.engine
    per = eng.per_service_power_w()
    last = tel.ledger.samples[-1]["tenant_w"]
    assert set(last) == {str(s) for s in per}
    for sid, w in per.items():
        assert last[str(sid)] == pytest.approx(w, rel=1e-9)
    out = tel.ledger.integrate()
    assert sum(out["joules_by_tenant"].values()) == pytest.approx(
        out["joules_total"], rel=1e-6)
    # per-tier proc watts decompose the Eq.(2) term
    tier = tel.ledger.samples[-1]["tier_w"]
    assert set(tier) == set(tiers_of(topo))
    assert sum(tier.values()) == pytest.approx(
        tel.ledger.samples[-1]["proc_w"], rel=1e-6)


def test_federated_ledger_regions_sum_exact():
    topo = topology.federated_scale(n_regions=3, n_olt=1, onus_per_olt=2,
                                    iot_per_onu=2, n_core=6)
    part = federation.RegionPartition.from_topology(topo)
    srcs = [int(r.proc_ids[0]) for r in part.regions]
    tel = Telemetry()
    sess = FederatedSession(topo, _spec(), telemetry=tel)
    sess.solve(vsr.random_vsrs(6, rng=1, n_vms=3, source_nodes=srcs))
    sess.tick(1.0)
    sess.add(vsr.random_vsrs(1, rng=9, n_vms=3, source_nodes=[srcs[1]]))
    assert tel.ledger.samples
    for s in tel.ledger.samples:
        assert sum(s["region_w"].values()) == pytest.approx(
            s["total_w"], rel=1e-9)
        assert s["net_w"] + s["proc_w"] == pytest.approx(
            s["total_w"], rel=1e-9)
    bd = sess.breakdown()
    last = tel.ledger.samples[-1]
    assert last["total_w"] == pytest.approx(bd.total_w, rel=1e-12)
    for g, w in enumerate(np.asarray(bd.regional_w)):
        assert last["region_w"][str(g)] == pytest.approx(float(w))


# ---------------------------------------------------------------------------
# convergence traces
# ---------------------------------------------------------------------------

def test_convergence_trace_fixed_length(topo):
    vs = vsr.random_vsrs(4, rng=0, n_vms=3)
    prob = power.build_problem(topo, vs)
    import jax
    X0 = np.zeros((prob.R, prob.V), np.int32)
    res = solvers.anneal(prob, jax.random.PRNGKey(0), X0, n_steps=64,
                         backend="delta", record_conv=True)
    assert set(res.conv) == {"best_obj", "accept_rate"}
    assert len(res.conv["best_obj"]) == 64
    assert len(res.conv["accept_rate"]) == 64
    bo = np.asarray(res.conv["best_obj"])
    assert (np.diff(bo) <= 1e-6).all()        # best objective is monotone
    ar = np.asarray(res.conv["accept_rate"])
    assert (ar >= 0).all() and (ar <= 1).all()
    # flag off -> no trace, and the jit cache key-space is UNTOUCHED
    before = dict(solvers.TRACE_COUNTS)
    res2 = solvers.anneal(prob, jax.random.PRNGKey(0), X0, n_steps=64,
                          backend="delta")
    assert res2.conv is None
    assert dict(solvers.TRACE_COUNTS) == before


def test_commit_records_convergence(topo):
    tel = Telemetry()
    sess = CFNSession(topo, _spec(), telemetry=tel)
    sess.add(_svc(0))
    sess.add(_svc(1))
    solves = [e for e in tel.events if e["type"] == "solve"]
    assert any("conv" in e for e in solves)
    ev = next(e for e in solves if "conv" in e)
    assert len(ev["conv"]["best_obj"]) <= 64  # downsampled payload bound


# ---------------------------------------------------------------------------
# monitor delegation
# ---------------------------------------------------------------------------

def test_monitor_mirror_parity():
    plain, tel = PlacementMonitor(), Telemetry()
    mirrored = PlacementMonitor()
    mirrored.attach_telemetry(tel)
    for mon in (plain, mirrored):
        for _ in range(3):
            mon.count("admission_rejected", detail="sla")
        mon.count("node_failed", n=2)
        mon.strand(7, t=1.0)
        mon.unstrand(7, t=3.5)
    assert mirrored.snapshot() == plain.snapshot()   # standalone unchanged
    assert mirrored.events == plain.events
    assert tel.counters["monitor.admission_rejected"] == 3
    assert tel.counters["monitor.node_failed"] == 2
    assert tel.gauges["monitor.stranded_open"] == 0
    assert tel.gauges["monitor.stranded_service_s"] == pytest.approx(2.5)


def test_monitor_ring_bound_unchanged_with_telemetry():
    tel = Telemetry()
    mon = PlacementMonitor(max_events=4)
    mon.attach_telemetry(tel)
    for i in range(10):
        mon.count("k", detail=str(i))
    assert len(mon.events) == 4
    assert mon.counters["k"] == 10 and tel.counters["monitor.k"] == 10


def test_monitor_merge_no_double_count():
    tel = Telemetry()
    a, b = PlacementMonitor(), PlacementMonitor()
    a.attach_telemetry(tel)
    b.attach_telemetry(tel)        # same registry: counts already there
    a.count("x")
    b.count("x")
    a.merge(b)
    assert a.counters["x"] == 2
    assert tel.counters["monitor.x"] == 2    # merge did NOT re-count
    c = PlacementMonitor()         # un-mirrored: merge must fold it in
    c.count("x", n=3)
    a.merge(c)
    assert a.counters["x"] == 5 and tel.counters["monitor.x"] == 5


# ---------------------------------------------------------------------------
# compile attribution
# ---------------------------------------------------------------------------

def test_compile_attribution_agrees(topo):
    tel = Telemetry()
    # unique shape (n_vms=5) so this scenario really compiles fresh
    sess = CFNSession(topo, _spec(), telemetry=tel)
    sess.add(_svc(0, n_vms=5))
    sess.add(_svc(1, n_vms=5))
    rep = tel.report()
    assert rep["compiles"]["agree"] is True
    assert rep["compiles"]["recorded"] == rep["compiles"]["live"]
    for rec in tel.compile_attribution():
        assert rec["entry"] in solvers.TRACE_COUNTS
        assert "[" in rec["fingerprint"]      # carries abstract shapes
    tel.close()                               # detaches the hook
    assert tel._trace_hook is None
    assert not solvers.TRACE_HOOKS or tel._trace_hook not in \
        solvers.TRACE_HOOKS


# ---------------------------------------------------------------------------
# the package lints clean
# ---------------------------------------------------------------------------

def test_telemetry_package_tracelint_clean():
    from repro.analysis import analyze_paths
    findings = analyze_paths([str(REPO / "src" / "repro" / "telemetry")])
    assert findings == [], [f"{f.rule}:{f.path}:{f.line}" for f in findings]


def test_report_cli_roundtrip(tmp_path):
    import subprocess
    import sys
    path = tmp_path / "cli.jsonl"
    with Telemetry(jsonl_path=str(path)) as tel:
        with tel.span("work"):
            pass
        tel.ledger.tick(0.0, total_w=5.0, net_w=2.0, proc_w=3.0)
    env_path = str(REPO / "src")
    for args in (["validate", str(path)], ["report", str(path), "--json"]):
        out = subprocess.run(
            [sys.executable, "-m", "repro.telemetry", *args],
            capture_output=True, text=True,
            env={"PYTHONPATH": env_path, "PATH": "/usr/bin:/bin"})
        assert out.returncode == 0, out.stderr
    rep = json.loads(subprocess.run(
        [sys.executable, "-m", "repro.telemetry", "report", str(path),
         "--json"], capture_output=True, text=True,
        env={"PYTHONPATH": env_path, "PATH": "/usr/bin:/bin"}).stdout)
    assert rep["spans"]["work"]["count"] == 1
