#!/usr/bin/env python
"""Validate BENCH_*.json tracker artifacts before CI uploads them.

Each tracker must parse as JSON and carry its expected top-level keys --
a renamed or dropped key silently breaks the cross-PR tracking the
benchmarks exist for, so the bench-artifact CI steps run this right
before upload.

Usage: python scripts/check_bench_schema.py [BENCH_file.json ...]
(no arguments: validate every known tracker present in the cwd; a known
tracker that is absent is skipped, an unknown BENCH file is an error).
Exit 0 when every checked file conforms.
"""
import json
import sys
from pathlib import Path

# tracker name -> required top-level keys (extra keys are allowed: new
# metrics may land; missing keys are what breaks downstream consumers)
EXPECTED = {
    "BENCH_churn.json": {"defrag", "objective_gap", "per_event",
                         "scenario", "speedup_wave_vs_per_event", "wave"},
    "BENCH_fault.json": {"federated", "scenario", "storms"},
    "BENCH_federated.json": {"federated", "flat",
                             "objective_ratio_fed_vs_flat", "scenario",
                             "speedup_vs_flat"},
    "BENCH_obs.json": {"identical_placements", "micro_ns_per_call", "off",
                       "on", "overhead_pct", "scenario"},
    "BENCH_online.json": {"defrag_sweep", "events", "scenario", "summary"},
    "BENCH_quality.json": {"quality", "scenario"},
    "BENCH_solver.json": {"anneal", "coordinate_sweep",
                          "max_delta_speedup_vs_full", "scenario"},
    "BENCH_sparse.json": {"f64_parity_paper_scale", "scenario", "sweeps"},
}


def check(path: Path) -> str | None:
    """Return an error string, or None when the tracker conforms."""
    if path.name not in EXPECTED:
        return (f"{path}: unknown tracker (add its schema to "
                f"scripts/check_bench_schema.py EXPECTED)")
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return f"{path}: unreadable ({e})"
    if not isinstance(data, dict):
        return f"{path}: top level is {type(data).__name__}, expected object"
    missing = EXPECTED[path.name] - set(data)
    if missing:
        return f"{path}: missing top-level key(s): {sorted(missing)}"
    return None


def main(argv) -> int:
    if argv:
        paths = [Path(a) for a in argv]
    else:
        paths = [p for name in sorted(EXPECTED) if (p := Path(name)).exists()]
    if not paths:
        print("check_bench_schema: no tracker files to check")
        return 0
    errors = [e for p in paths if (e := check(p))]
    for e in errors:
        print(f"FAIL: {e}")
    for p in paths:
        if not check(p):
            print(f"ok: {p}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
